"""Tests for the Jackson network model (paper Eq. 3 + traffic equations)."""

import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no dev deps installed — deterministic fallback sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.jackson import (
    OperatorSpec,
    Topology,
    UnstableTopologyError,
    solve_traffic_equations,
)


def test_chain_arrival_rates():
    # VLD-like chain: spout -> extractor -> matcher -> aggregator
    top = Topology.chain([("ext", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=13.0)
    np.testing.assert_allclose(top.arrival_rates, [13.0, 13.0, 13.0])
    np.testing.assert_allclose(top.visit_counts, [1.0, 1.0, 1.0])


def test_fanout_multiplicity():
    # Extractor emits on average 7 features per frame (routing weight > 1).
    ops = [OperatorSpec("ext", 2.0), OperatorSpec("match", 30.0)]
    routing = np.array([[0.0, 7.0], [0.0, 0.0]])
    top = Topology(ops, np.array([13.0, 0.0]), routing)
    np.testing.assert_allclose(top.arrival_rates, [13.0, 91.0])


def test_split_join():
    # A -> (B, C) -> D  (paper Fig. 2 without the loop)
    ops = [OperatorSpec(n, 10.0) for n in "ABCD"]
    routing = np.zeros((4, 4))
    routing[0][1] = 0.5  # A->B with prob .5
    routing[0][2] = 0.5  # A->C with prob .5
    routing[1][3] = 1.0
    routing[2][3] = 1.0
    top = Topology(ops, np.array([8.0, 0, 0, 0]), routing)
    np.testing.assert_allclose(top.arrival_rates, [8.0, 4.0, 4.0, 8.0])


def test_feedback_loop():
    # FPD-style self-loop: detector re-notifies itself with prob 0.4.
    ops = [OperatorSpec("gen", 10.0), OperatorSpec("det", 10.0), OperatorSpec("rep", 10.0)]
    routing = np.zeros((3, 3))
    routing[0][1] = 1.0
    routing[1][1] = 0.4  # self loop (leaks 0.6)
    routing[1][2] = 0.6
    top = Topology(ops, np.array([6.0, 0, 0]), routing)
    lam = top.arrival_rates
    # det sees gen traffic amplified by 1/(1-0.4)
    assert lam[1] == pytest.approx(6.0 / 0.6)
    assert lam[2] == pytest.approx(6.0)


def test_decode_self_loop_visit_count():
    """Autoregressive decode: loop prob p = 1 - 1/L gives L visits."""
    L = 64.0
    p = 1.0 - 1.0 / L
    ops = [OperatorSpec("prefill", 5.0), OperatorSpec("decode", 500.0)]
    routing = np.array([[0.0, 1.0], [0.0, p]])
    top = Topology(ops, np.array([2.0, 0.0]), routing)
    assert top.visit_counts[1] == pytest.approx(L)


def test_non_leaking_loop_raises():
    ops = [OperatorSpec("a", 1.0), OperatorSpec("b", 1.0)]
    routing = np.array([[0.0, 1.0], [1.0, 0.0]])  # a->b->a forever
    with pytest.raises(UnstableTopologyError):
        Topology(ops, np.array([1.0, 0.0]), routing).arrival_rates


def test_expected_sojourn_eq3_weighting():
    # Two-op chain with known M/M/1 values.
    ops = [OperatorSpec("a", 10.0), OperatorSpec("b", 20.0)]
    routing = np.array([[0.0, 1.0], [0.0, 0.0]])
    top = Topology(ops, np.array([4.0, 0.0]), routing)
    t = top.expected_sojourn([1, 1])
    expect = 1.0 / (10 - 4) + 1.0 / (20 - 4)
    assert t == pytest.approx(expect, rel=1e-12)


def test_sojourn_infinite_when_any_operator_unstable():
    top = Topology.chain([("a", 10.0), ("b", 1.0)], lam0=4.0)
    assert top.expected_sojourn([1, 1]) == math.inf  # b: k*mu=1 < 4
    assert math.isfinite(top.expected_sojourn([1, 5]))


def test_min_feasible_allocation():
    top = Topology.chain([("a", 2.0), ("b", 5.0), ("c", 50.0)], lam0=13.0)
    np.testing.assert_array_equal(top.min_feasible_allocation(), [7, 3, 1])


@given(
    lam0=st.floats(min_value=0.5, max_value=30.0),
    p=st.floats(min_value=0.0, max_value=0.9),
    fanout=st.floats(min_value=0.5, max_value=4.0),
)
@settings(max_examples=100, deadline=None)
def test_traffic_equations_conservation(lam0, p, fanout):
    """Solved rates satisfy lam = lam0 + P^T lam exactly."""
    routing = np.array(
        [
            [0.0, fanout, 0.0],
            [0.0, p, 1.0 - p],
            [0.1, 0.0, 0.0],  # loop back to source with prob .1
        ]
    )
    lam0_vec = np.array([lam0, 0.0, 0.0])
    lam = solve_traffic_equations(lam0_vec, routing)
    np.testing.assert_allclose(lam, lam0_vec + routing.T @ lam, rtol=1e-9, atol=1e-9)


def test_group_scaling_mode():
    """TPU chip-group extension: one gang with mu(k) = mu*k*eff(k)."""
    op = OperatorSpec("train", mu=2.0, scaling="group", group_alpha=0.05)
    # k=1: plain M/M/1 at mu=2
    assert op.sojourn(1, 1.0) == pytest.approx(1.0 / (2.0 - 1.0))
    # k=4: mu_eff = 2*4/(1+0.05*3) = 6.956...; still finite and smaller
    t4 = op.sojourn(4, 1.0)
    assert t4 < op.sojourn(1, 1.0)
    assert op.min_feasible_k(10.0) >= 5  # needs mu_eff > 10
    assert math.isfinite(op.sojourn(op.min_feasible_k(10.0), 10.0))
