"""Tests for the VLD / FPD applications and the live StreamEngine."""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.streaming.apps.fpd import (
    FPDConfig,
    SlidingWindowState,
    candidate_patterns,
    maximal_frequent,
    pack_itemset,
    random_transaction,
    support_counts,
)
from repro.streaming.apps.vld import (
    VLDConfig,
    aggregate_matches,
    extract_features,
    logo_library,
    make_frame,
    match_features,
)
from repro.streaming.engine import Operator, StreamEngine


# --------------------------------------------------------------------- #
# VLD
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def vld():
    cfg = VLDConfig()
    lib = logo_library(cfg)
    return cfg, lib


def test_extract_features_shapes_and_validity(vld):
    cfg, lib = vld
    rng = np.random.default_rng(0)
    frame = make_frame(cfg, rng, np.asarray(lib), with_logo=True)
    desc, valid = extract_features(jnp.asarray(frame), cfg)
    assert desc.shape == (cfg.max_keypoints, cfg.patch * cfg.patch)
    assert valid.shape == (cfg.max_keypoints,)
    assert bool(valid.any())
    assert not bool(jnp.isnan(desc).any())
    # descriptors are unit-normalised where valid
    norms = jnp.linalg.norm(desc, axis=1)
    assert bool(jnp.all(jnp.where(valid, jnp.abs(norms - 1.0) < 1e-3, True)))


def test_logo_frames_detect_more_than_background(vld):
    cfg, lib = vld
    rng = np.random.default_rng(1)
    hits_logo, hits_bg = 0, 0
    for i in range(8):
        for with_logo in (True, False):
            frame = make_frame(cfg, rng, np.asarray(lib), with_logo=with_logo)
            desc, valid = extract_features(jnp.asarray(frame), cfg)
            counts = match_features(desc, valid, lib, cfg.match_threshold)
            det = aggregate_matches(
                counts, cfg.n_logos, cfg.descriptors_per_logo, cfg.detect_threshold
            )
            if with_logo:
                hits_logo += int(det.sum())
            else:
                hits_bg += int(det.sum())
    assert hits_logo > hits_bg  # logo frames must trigger more detections


def test_feature_count_varies_with_content(vld):
    """The data-dependent fan-out DRS must track (paper §I)."""
    cfg, lib = vld
    rng = np.random.default_rng(2)
    counts = []
    for _ in range(10):
        frame = make_frame(cfg, rng, np.asarray(lib), with_logo=rng.random() < 0.5)
        _, valid = extract_features(jnp.asarray(frame), cfg)
        counts.append(int(valid.sum()))
    assert len(set(counts)) > 1  # genuinely varies


# --------------------------------------------------------------------- #
# FPD
# --------------------------------------------------------------------- #
def test_pack_and_candidates():
    cfg = FPDConfig(n_items=8, max_pattern_size=2)
    mask = pack_itemset([1, 3, 5])
    cands = candidate_patterns(mask, cfg)
    # 3 singletons + 3 pairs
    assert len(cands) == 6
    assert pack_itemset([1, 3]) in cands
    assert pack_itemset([1, 3, 5]) not in cands  # size > max_pattern_size


def test_support_counts_basic():
    pats = jnp.asarray(
        [pack_itemset([0]), pack_itemset([1]), pack_itemset([0, 1])], dtype=jnp.uint32
    )
    window = jnp.asarray(
        [pack_itemset([0, 1]), pack_itemset([0]), pack_itemset([0, 1, 2])],
        dtype=jnp.uint32,
    )
    counts = support_counts(pats, window)
    np.testing.assert_array_equal(np.asarray(counts), [3, 2, 2])


def test_maximal_frequent_definition():
    """MFP: frequent itself, no frequent strict superset (paper's (a)+(b))."""
    pats = jnp.asarray(
        [
            pack_itemset([0]),
            pack_itemset([1]),
            pack_itemset([0, 1]),
            pack_itemset([2]),
        ],
        dtype=jnp.uint32,
    )
    counts = jnp.asarray([10, 9, 8, 3], dtype=jnp.int32)
    mfp = maximal_frequent(pats, counts, jnp.int32(5))
    # {0},{1} are frequent but {0,1} is a frequent superset -> not maximal
    np.testing.assert_array_equal(np.asarray(mfp), [False, False, True, False])


def test_sliding_window_state_machine():
    cfg = FPDConfig(n_items=6, max_pattern_size=2, window=4, support_threshold=3)
    st = SlidingWindowState(cfg)
    m = pack_itemset([0, 1])
    changed_total = []
    for _ in range(3):
        changed_total += st.apply(m, entering=True)
    assert len(changed_total) > 0  # {0,1} became MFP at count 3
    assert pack_itemset([0, 1]) in st.current_mfps()
    # Window overflow evicts the oldest and counts stay consistent.
    for _ in range(4):
        st.apply(pack_itemset([2]), entering=True)
    idx = int(np.nonzero(st.patterns == np.uint32(m))[0][0])
    assert st.counts[idx] < 3  # evicted below threshold
    assert m not in st.current_mfps()


def test_window_eviction_keeps_counts_nonnegative():
    cfg = FPDConfig(n_items=5, max_pattern_size=2, window=8, support_threshold=2)
    st = SlidingWindowState(cfg)
    rng = np.random.default_rng(3)
    for _ in range(50):
        st.apply(random_transaction(cfg, rng), entering=True)
    assert (st.counts >= 0).all()
    assert len(st.window) <= cfg.window
    # counts match a from-scratch recount of the window
    recount = np.asarray(
        support_counts(
            jnp.asarray(st.patterns), jnp.asarray(np.array(st.window, dtype=np.uint32))
        )
    )
    np.testing.assert_array_equal(st.counts, recount)


# --------------------------------------------------------------------- #
# Live engine end-to-end
# --------------------------------------------------------------------- #
def test_engine_chain_completes_and_measures():
    log = []
    ops = [
        Operator("a", lambda x: [("b", x + 1)]),
        Operator("b", lambda x: [("c", x * 2)]),
        Operator("c", lambda x: log.append(x) or []),
    ]
    eng = StreamEngine(ops)
    eng.measurer.pull(time.time())
    eng.start({"a": 1, "b": 2, "c": 1})
    for i in range(50):
        eng.inject("a", i)
    assert eng.drain(timeout=10.0)
    eng.stop()
    assert sorted(log) == [(i + 1) * 2 for i in range(50)]
    assert len(eng.completed_sojourns) == 50
    snap = eng.measurer.pull(time.time())
    assert snap.lam_hat[0] > 0 and snap.lam0_hat > 0


def test_engine_rescale_midstream():
    ops = [Operator("a", lambda x: [])]
    eng = StreamEngine(ops)
    eng.start({"a": 1})
    assert eng.k()["a"] == 1
    eng.scale_to({"a": 4})
    assert eng.k()["a"] == 4
    for i in range(20):
        eng.inject("a", i)
    assert eng.drain(timeout=5.0)
    eng.scale_to({"a": 2})
    assert eng.k()["a"] == 2
    for i in range(10):
        eng.inject("a", i)
    assert eng.drain(timeout=5.0)
    eng.stop()
    assert len(eng.completed_sojourns) == 30


def test_engine_vld_end_to_end():
    """VLD through the declarative API: one AppGraph, engine session."""
    from repro.streaming.apps.vld import build_vld_graph

    cfg = VLDConfig(height=32, width=32, max_keypoints=16, n_logos=4)
    lib = logo_library(cfg)
    graph, detections = build_vld_graph(cfg, lib)
    session = graph.bind("engine")
    session.start({"extract": 2, "match": 1, "aggregate": 1})
    rng = np.random.default_rng(5)
    n = 12
    for _ in range(n):
        session.inject(make_frame(cfg, rng, np.asarray(lib), rng.random() < 0.5))
    assert session.drain(timeout=30.0)
    session.stop()
    assert len(detections) == n
    assert all(d.shape == (cfg.n_logos,) for d in detections)


def test_engine_fpd_end_to_end_with_self_loop():
    """FPD through the declarative API: the self-loop is a typed edge."""
    from repro.streaming.apps.fpd import build_fpd_graph

    cfg = FPDConfig(n_items=8, max_pattern_size=2, window=16, support_threshold=4)
    graph, state, reports = build_fpd_graph(cfg)
    session = graph.bind("engine")
    session.start({"generate": 1, "detect": 1, "report": 1})
    rng = np.random.default_rng(6)
    hot = pack_itemset([0, 1])
    for i in range(24):
        mask = hot if i % 2 == 0 else random_transaction(cfg, rng)
        session.inject((mask, True))
    assert session.drain(timeout=30.0)
    session.stop()
    assert len(reports) > 0  # MFP state changes were reported
    assert hot in state.current_mfps()  # the hot pattern is maximal-frequent
