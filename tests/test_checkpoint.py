"""CheckpointStore: atomicity, async, exotic dtypes, pruning, elasticity."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore


def tree(seed=0, dtype=jnp.float32):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4), dtype=dtype), "b": jnp.zeros((4,), dtype)},
        "step": jnp.int32(7),
    }


def test_save_restore_roundtrip(tmp_path):
    st = CheckpointStore(tmp_path)
    t = tree()
    st.save(10, t, extra={"data": {"step": 10}})
    out, extra = st.restore(t)
    np.testing.assert_array_equal(out["params"]["w"], t["params"]["w"])
    assert extra["data"]["step"] == 10
    assert st.latest_step() == 10


def test_bfloat16_roundtrip(tmp_path):
    st = CheckpointStore(tmp_path)
    t = tree(dtype=jnp.bfloat16)
    st.save(1, t)
    out, _ = st.restore(t)
    np.testing.assert_array_equal(
        np.asarray(out["params"]["w"], np.float32), np.asarray(t["params"]["w"], np.float32)
    )
    assert out["params"]["w"].dtype == jnp.bfloat16


def test_dtype_cast_on_restore(tmp_path):
    """f32 checkpoint restores onto a bf16 template (elastic moment dtype)."""
    st = CheckpointStore(tmp_path)
    t32 = tree(dtype=jnp.float32)
    st.save(1, t32)
    t16 = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x, t32
    )
    out, _ = st.restore(t16)
    assert out["params"]["w"].dtype == jnp.bfloat16


def test_async_save_then_wait(tmp_path):
    st = CheckpointStore(tmp_path)
    st.save_async(5, tree())
    st.wait()
    assert st.latest_step() == 5


def test_shape_mismatch_raises(tmp_path):
    st = CheckpointStore(tmp_path)
    st.save(1, tree())
    bad = tree()
    bad["params"]["w"] = jnp.zeros((9, 4))
    with pytest.raises(ValueError, match="shape mismatch"):
        st.restore(bad)


def test_prune_keeps_newest(tmp_path):
    st = CheckpointStore(tmp_path)
    for s in (1, 2, 3, 4, 5):
        st.save(s, tree())
    removed = st.prune(keep=2)
    assert removed == 3
    assert st.latest_step() == 5
    with pytest.raises(Exception):
        st.restore(tree(), step=1)  # pruned


def test_atomic_overwrite(tmp_path):
    """Re-saving the same step replaces it atomically (no .tmp residue)."""
    st = CheckpointStore(tmp_path)
    st.save(1, tree(seed=0))
    st.save(1, tree(seed=1))
    out, _ = st.restore(tree(), step=1)
    np.testing.assert_array_equal(out["params"]["w"], tree(seed=1)["params"]["w"])
    assert not list(tmp_path.glob("*.tmp"))

# --------------------------------------------------------------------------- #
# The fused control plane's donated carry (DESIGN.md §16): checkpoint ->
# restore -> resume must be bit-identical to the straight-through run.
# --------------------------------------------------------------------------- #
def _control_loop(proactive=None):
    import repro.core.controller as ctl
    from repro.api.session import ScenarioRunner
    from repro.streaming.scenarios import scenario_matrix

    scens = [
        s.with_(negotiated=False)
        for s in scenario_matrix(4, seed=19, horizon=20.0, warmup=5.0, dt=0.05)
    ]
    r = ScenarioRunner(scens, tick_interval=5.0, backend="jax",
                       proactive=proactive)
    loop, n_ticks = ctl.make_fused_loop(
        r.arrays, r.static, r._params(),
        steps_per_tick=r._steps_per_tick, warmup_seconds=scens[0].warmup,
        proactive=r.proactive_cfg,
    )
    return r, loop, n_ticks


@pytest.mark.parametrize("proactive", [False, True],
                         ids=["reactive", "proactive"])
def test_controller_state_checkpoint_resume_bit_identical(tmp_path, proactive):
    """Save the ControllerState mid-horizon, restore into a fresh loop,
    run the rest: outputs match a straight-through run bit for bit
    (including the ForecastState leaves on the proactive path)."""
    cfg = None
    if proactive:
        from repro.forecast.mpc import MPCConfig, PredictorParams

        cfg = MPCConfig(horizon=3, window=12, min_scored=2,
                        predictor=PredictorParams(kind="holt", alpha=0.6,
                                                  beta=0.4))
    r, loop, n_ticks = _control_loop(cfg)
    ref = {k: np.asarray(v) for k, v in loop(r.k).items()}

    r2, loop2, _ = _control_loop(cfg)
    state = loop2.init(r2.k)
    state, _ = loop2.run(state, 2)
    st = CheckpointStore(tmp_path)
    st.save(2, state)

    # Fresh loop (new compiled executables), restore into a template built
    # from init() — the shapes/dtypes of a tick-0 carry.
    r3, loop3, _ = _control_loop(cfg)
    template = loop3.init(r3.k)
    restored, _extra = st.restore(template, step=2)
    import repro.core.controller as ctl

    restored = ctl.ControllerState(*restored)
    assert int(restored.tick) == 2
    if proactive:
        assert len(restored.fstate) > 0
    state3, out = loop3.run(restored)  # the remaining n_ticks - 2 windows
    for key in ("codes", "k", "applied"):
        np.testing.assert_array_equal(
            np.asarray(out[key]), ref[key][2:], err_msg=key
        )
    for key in ("k_final", "q_final", "offered", "served", "dropped",
                "ext_admitted", "ext_offered", "q_int", "q_max"):
        np.testing.assert_array_equal(np.asarray(out[key]), ref[key],
                                      err_msg=key)
    if proactive:
        np.testing.assert_array_equal(np.asarray(out["mpc_used"]),
                                      ref["mpc_used"][2:])


def test_controller_state_checkpoint_is_layout_independent(tmp_path):
    """The carry saved from an unsharded run restores onto a mesh-sharded
    loop (and vice versa is covered by shape identity): the store keys by
    pytree path, not device layout."""
    import jax as _jax

    r, loop, n_ticks = _control_loop()
    state = loop.init(r.k)
    state, _ = loop.run(state, 1)
    st = CheckpointStore(tmp_path)
    st.save(1, state)
    if len(_jax.devices()) < 2:
        pytest.skip("mesh restore leg needs >= 2 devices")
    import repro.core.controller as ctl
    from repro.api.session import ScenarioRunner
    from repro.distributed.sharding import fleet_mesh
    from repro.streaming.scenarios import scenario_matrix

    scens = [
        s.with_(negotiated=False)
        for s in scenario_matrix(4, seed=19, horizon=20.0, warmup=5.0, dt=0.05)
    ]
    rm = ScenarioRunner(scens, tick_interval=5.0, backend="jax",
                        mesh=fleet_mesh(2))
    loop_m, _ = ctl.make_fused_loop(
        rm.arrays, rm.static, rm._params(),
        steps_per_tick=rm._steps_per_tick, warmup_seconds=scens[0].warmup,
        mesh=fleet_mesh(2),
    )
    template = loop_m.init(rm.k)
    restored, _ = st.restore(template, step=1)
    restored = ctl.ControllerState(*restored)
    _, out = loop_m.run(restored)
    ref = {k: np.asarray(v) for k, v in loop(r.k).items()}
    np.testing.assert_array_equal(np.asarray(out["codes"]), ref["codes"][1:])
    np.testing.assert_array_equal(np.asarray(out["k_final"]), ref["k_final"])
