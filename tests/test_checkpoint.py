"""CheckpointStore: atomicity, async, exotic dtypes, pruning, elasticity."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore


def tree(seed=0, dtype=jnp.float32):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4), dtype=dtype), "b": jnp.zeros((4,), dtype)},
        "step": jnp.int32(7),
    }


def test_save_restore_roundtrip(tmp_path):
    st = CheckpointStore(tmp_path)
    t = tree()
    st.save(10, t, extra={"data": {"step": 10}})
    out, extra = st.restore(t)
    np.testing.assert_array_equal(out["params"]["w"], t["params"]["w"])
    assert extra["data"]["step"] == 10
    assert st.latest_step() == 10


def test_bfloat16_roundtrip(tmp_path):
    st = CheckpointStore(tmp_path)
    t = tree(dtype=jnp.bfloat16)
    st.save(1, t)
    out, _ = st.restore(t)
    np.testing.assert_array_equal(
        np.asarray(out["params"]["w"], np.float32), np.asarray(t["params"]["w"], np.float32)
    )
    assert out["params"]["w"].dtype == jnp.bfloat16


def test_dtype_cast_on_restore(tmp_path):
    """f32 checkpoint restores onto a bf16 template (elastic moment dtype)."""
    st = CheckpointStore(tmp_path)
    t32 = tree(dtype=jnp.float32)
    st.save(1, t32)
    t16 = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x, t32
    )
    out, _ = st.restore(t16)
    assert out["params"]["w"].dtype == jnp.bfloat16


def test_async_save_then_wait(tmp_path):
    st = CheckpointStore(tmp_path)
    st.save_async(5, tree())
    st.wait()
    assert st.latest_step() == 5


def test_shape_mismatch_raises(tmp_path):
    st = CheckpointStore(tmp_path)
    st.save(1, tree())
    bad = tree()
    bad["params"]["w"] = jnp.zeros((9, 4))
    with pytest.raises(ValueError, match="shape mismatch"):
        st.restore(bad)


def test_prune_keeps_newest(tmp_path):
    st = CheckpointStore(tmp_path)
    for s in (1, 2, 3, 4, 5):
        st.save(s, tree())
    removed = st.prune(keep=2)
    assert removed == 3
    assert st.latest_step() == 5
    with pytest.raises(Exception):
        st.restore(tree(), step=1)  # pruned


def test_atomic_overwrite(tmp_path):
    """Re-saving the same step replaces it atomically (no .tmp residue)."""
    st = CheckpointStore(tmp_path)
    st.save(1, tree(seed=0))
    st.save(1, tree(seed=1))
    out, _ = st.restore(tree(), step=1)
    np.testing.assert_array_equal(out["params"]["w"], tree(seed=1)["params"]["w"])
    assert not list(tmp_path.glob("*.tmp"))


@pytest.mark.parametrize("name", ["float8_e4m3fn", "float8_e5m2"])
def test_float8_roundtrip(tmp_path, name):
    """The remaining ``_EXOTIC_DTYPES`` paths (bf16 covered above): f8
    leaves save as uint8 carriers and restore with the logical dtype and
    the exact bit pattern."""
    import ml_dtypes

    dt = np.dtype(getattr(ml_dtypes, name))
    st = CheckpointStore(tmp_path)
    t = {"w": np.arange(-8, 8, dtype=np.float32).astype(dt), "step": np.int32(3)}
    st.save(1, t)
    out, _ = st.restore(t)
    assert out["w"].dtype == dt
    np.testing.assert_array_equal(out["w"].view(np.uint8), t["w"].view(np.uint8))


def test_namedtuple_and_forecast_state_roundtrip(tmp_path):
    """A carry shaped like the fused loop's: a NamedTuple wrapping mixed
    dtypes, a nested aggregate tuple, and real ForecastState leaves —
    keys come from the pytree path, so tuple indices must round-trip."""
    from typing import NamedTuple

    from repro.forecast.mpc import MPCConfig, forecast_init_state

    class Carry(NamedTuple):
        q: np.ndarray
        k: np.ndarray
        acc: tuple
        fstate: tuple

    rng = np.random.default_rng(5)
    fstate = forecast_init_state(2, 3, MPCConfig(window=6))
    carry = Carry(
        q=rng.uniform(0, 9, (2, 3)),
        k=rng.integers(1, 8, (2, 3)).astype(np.int32),
        acc=(rng.uniform(0, 1, (2, 3)), rng.uniform(0, 1, (2, 3))),
        fstate=fstate,
    )
    st = CheckpointStore(tmp_path)
    st.save(4, carry)
    out, _ = st.restore(carry)
    restored = Carry(*out)
    np.testing.assert_array_equal(restored.q, carry.q)
    np.testing.assert_array_equal(restored.k, carry.k)
    assert restored.k.dtype == np.int32
    for got, want in zip(restored.acc, carry.acc):
        np.testing.assert_array_equal(got, want)
    assert len(restored.fstate) == len(fstate)
    for got, want in zip(restored.fstate, fstate):
        np.testing.assert_array_equal(got, want)


def test_async_save_ordering_one_in_flight(tmp_path):
    """``save_async`` joins the previous in-flight writer before
    snapshotting, so back-to-back calls land every step in order; a final
    ``wait`` makes the last one durable."""
    st = CheckpointStore(tmp_path)
    trees = {s: tree(seed=s) for s in (1, 2, 3)}
    for s, t in trees.items():
        st.save_async(s, t)
    st.wait()
    assert st.save_count == 3
    assert st.latest_step() == 3
    for s, t in trees.items():
        out, _ = st.restore(tree(), step=s)
        np.testing.assert_array_equal(out["params"]["w"], t["params"]["w"])
    st.wait()  # idempotent after join


def test_latest_step_ignores_partial_and_corrupt_dirs(tmp_path):
    """A crash can leave manifest-less step dirs, .tmp staging dirs, and
    junk names behind — ``latest_step`` must only count complete saves,
    and ``restore`` must land on that complete step."""
    st = CheckpointStore(tmp_path)
    t = tree()
    st.save(3, t)
    # partial: step dir without a manifest (crash mid-write before rename
    # would normally leave only .tmp, but a torn unlink can leave this)
    (tmp_path / "step_0000000009").mkdir()
    # staging dir from an interrupted save
    (tmp_path / "step_0000000007.tmp").mkdir()
    # junk that matches the glob but not the name schema
    (tmp_path / "step_garbage").mkdir()
    assert st.latest_step() == 3
    out, _ = st.restore(t)
    np.testing.assert_array_equal(out["params"]["w"], t["params"]["w"])


def test_mesh_save_restores_onto_unsharded_template(tmp_path):
    """The reverse of the layout-independence test below: a carry saved
    from a mesh-sharded loop restores onto the unsharded loop's template
    (per-leaf .npy files are device-layout-free host arrays)."""
    import jax as _jax

    if len(_jax.devices()) < 2:
        pytest.skip("mesh save leg needs >= 2 devices")
    import repro.core.controller as ctl
    from repro.distributed.sharding import fleet_mesh
    from repro.streaming.scenarios import scenario_matrix

    from repro.api.session import ScenarioRunner

    scens = [
        s.with_(negotiated=False)
        for s in scenario_matrix(4, seed=19, horizon=20.0, warmup=5.0, dt=0.05)
    ]
    rm = ScenarioRunner(scens, tick_interval=5.0, backend="jax",
                        mesh=fleet_mesh(2))
    loop_m, _ = ctl.make_fused_loop(
        rm.arrays, rm.static, rm._params(),
        steps_per_tick=rm._steps_per_tick, warmup_seconds=scens[0].warmup,
        mesh=fleet_mesh(2),
    )
    state = loop_m.init(rm.k)
    state, _ = loop_m.run(state, 1)
    st = CheckpointStore(tmp_path)
    st.save(1, state)

    r, loop, _ = _control_loop()
    # mesh-padded batch extent == real extent here (4 lanes, 2 devices),
    # so the unsharded template matches leaf-for-leaf.
    restored, _ = st.restore(loop.init(r.k), step=1)
    restored = ctl.ControllerState(*restored)
    _, out = loop.run(restored)
    ref = {k: np.asarray(v) for k, v in loop(r.k).items()}
    np.testing.assert_array_equal(np.asarray(out["codes"]), ref["codes"][1:])
    np.testing.assert_array_equal(np.asarray(out["k_final"]), ref["k_final"])

# --------------------------------------------------------------------------- #
# The fused control plane's donated carry (DESIGN.md §16): checkpoint ->
# restore -> resume must be bit-identical to the straight-through run.
# --------------------------------------------------------------------------- #
def _control_loop(proactive=None):
    import repro.core.controller as ctl
    from repro.api.session import ScenarioRunner
    from repro.streaming.scenarios import scenario_matrix

    scens = [
        s.with_(negotiated=False)
        for s in scenario_matrix(4, seed=19, horizon=20.0, warmup=5.0, dt=0.05)
    ]
    r = ScenarioRunner(scens, tick_interval=5.0, backend="jax",
                       proactive=proactive)
    loop, n_ticks = ctl.make_fused_loop(
        r.arrays, r.static, r._params(),
        steps_per_tick=r._steps_per_tick, warmup_seconds=scens[0].warmup,
        proactive=r.proactive_cfg,
    )
    return r, loop, n_ticks


@pytest.mark.parametrize("proactive", [False, True],
                         ids=["reactive", "proactive"])
def test_controller_state_checkpoint_resume_bit_identical(tmp_path, proactive):
    """Save the ControllerState mid-horizon, restore into a fresh loop,
    run the rest: outputs match a straight-through run bit for bit
    (including the ForecastState leaves on the proactive path)."""
    cfg = None
    if proactive:
        from repro.forecast.mpc import MPCConfig, PredictorParams

        cfg = MPCConfig(horizon=3, window=12, min_scored=2,
                        predictor=PredictorParams(kind="holt", alpha=0.6,
                                                  beta=0.4))
    r, loop, n_ticks = _control_loop(cfg)
    ref = {k: np.asarray(v) for k, v in loop(r.k).items()}

    r2, loop2, _ = _control_loop(cfg)
    state = loop2.init(r2.k)
    state, _ = loop2.run(state, 2)
    st = CheckpointStore(tmp_path)
    st.save(2, state)

    # Fresh loop (new compiled executables), restore into a template built
    # from init() — the shapes/dtypes of a tick-0 carry.
    r3, loop3, _ = _control_loop(cfg)
    template = loop3.init(r3.k)
    restored, _extra = st.restore(template, step=2)
    import repro.core.controller as ctl

    restored = ctl.ControllerState(*restored)
    assert int(restored.tick) == 2
    if proactive:
        assert len(restored.fstate) > 0
    state3, out = loop3.run(restored)  # the remaining n_ticks - 2 windows
    for key in ("codes", "k", "applied"):
        np.testing.assert_array_equal(
            np.asarray(out[key]), ref[key][2:], err_msg=key
        )
    for key in ("k_final", "q_final", "offered", "served", "dropped",
                "ext_admitted", "ext_offered", "q_int", "q_max"):
        np.testing.assert_array_equal(np.asarray(out[key]), ref[key],
                                      err_msg=key)
    if proactive:
        np.testing.assert_array_equal(np.asarray(out["mpc_used"]),
                                      ref["mpc_used"][2:])


def test_controller_state_checkpoint_is_layout_independent(tmp_path):
    """The carry saved from an unsharded run restores onto a mesh-sharded
    loop (and vice versa is covered by shape identity): the store keys by
    pytree path, not device layout."""
    import jax as _jax

    r, loop, n_ticks = _control_loop()
    state = loop.init(r.k)
    state, _ = loop.run(state, 1)
    st = CheckpointStore(tmp_path)
    st.save(1, state)
    if len(_jax.devices()) < 2:
        pytest.skip("mesh restore leg needs >= 2 devices")
    import repro.core.controller as ctl
    from repro.api.session import ScenarioRunner
    from repro.distributed.sharding import fleet_mesh
    from repro.streaming.scenarios import scenario_matrix

    scens = [
        s.with_(negotiated=False)
        for s in scenario_matrix(4, seed=19, horizon=20.0, warmup=5.0, dt=0.05)
    ]
    rm = ScenarioRunner(scens, tick_interval=5.0, backend="jax",
                        mesh=fleet_mesh(2))
    loop_m, _ = ctl.make_fused_loop(
        rm.arrays, rm.static, rm._params(),
        steps_per_tick=rm._steps_per_tick, warmup_seconds=scens[0].warmup,
        mesh=fleet_mesh(2),
    )
    template = loop_m.init(rm.k)
    restored, _ = st.restore(template, step=1)
    restored = ctl.ControllerState(*restored)
    _, out = loop_m.run(restored)
    ref = {k: np.asarray(v) for k, v in loop(r.k).items()}
    np.testing.assert_array_equal(np.asarray(out["codes"]), ref["codes"][1:])
    np.testing.assert_array_equal(np.asarray(out["k_final"]), ref["k_final"])
