"""CheckpointStore: atomicity, async, exotic dtypes, pruning, elasticity."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore


def tree(seed=0, dtype=jnp.float32):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4), dtype=dtype), "b": jnp.zeros((4,), dtype)},
        "step": jnp.int32(7),
    }


def test_save_restore_roundtrip(tmp_path):
    st = CheckpointStore(tmp_path)
    t = tree()
    st.save(10, t, extra={"data": {"step": 10}})
    out, extra = st.restore(t)
    np.testing.assert_array_equal(out["params"]["w"], t["params"]["w"])
    assert extra["data"]["step"] == 10
    assert st.latest_step() == 10


def test_bfloat16_roundtrip(tmp_path):
    st = CheckpointStore(tmp_path)
    t = tree(dtype=jnp.bfloat16)
    st.save(1, t)
    out, _ = st.restore(t)
    np.testing.assert_array_equal(
        np.asarray(out["params"]["w"], np.float32), np.asarray(t["params"]["w"], np.float32)
    )
    assert out["params"]["w"].dtype == jnp.bfloat16


def test_dtype_cast_on_restore(tmp_path):
    """f32 checkpoint restores onto a bf16 template (elastic moment dtype)."""
    st = CheckpointStore(tmp_path)
    t32 = tree(dtype=jnp.float32)
    st.save(1, t32)
    t16 = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x, t32
    )
    out, _ = st.restore(t16)
    assert out["params"]["w"].dtype == jnp.bfloat16


def test_async_save_then_wait(tmp_path):
    st = CheckpointStore(tmp_path)
    st.save_async(5, tree())
    st.wait()
    assert st.latest_step() == 5


def test_shape_mismatch_raises(tmp_path):
    st = CheckpointStore(tmp_path)
    st.save(1, tree())
    bad = tree()
    bad["params"]["w"] = jnp.zeros((9, 4))
    with pytest.raises(ValueError, match="shape mismatch"):
        st.restore(bad)


def test_prune_keeps_newest(tmp_path):
    st = CheckpointStore(tmp_path)
    for s in (1, 2, 3, 4, 5):
        st.save(s, tree())
    removed = st.prune(keep=2)
    assert removed == 3
    assert st.latest_step() == 5
    with pytest.raises(Exception):
        st.restore(tree(), step=1)  # pruned


def test_atomic_overwrite(tmp_path):
    """Re-saving the same step replaces it atomically (no .tmp residue)."""
    st = CheckpointStore(tmp_path)
    st.save(1, tree(seed=0))
    st.save(1, tree(seed=1))
    out, _ = st.restore(tree(), step=1)
    np.testing.assert_array_equal(out["params"]["w"], tree(seed=1)["params"]["w"])
    assert not list(tmp_path.glob("*.tmp"))
