"""Tests for measurer / scheduler / negotiator / rebalance modules."""


import numpy as np
import pytest

from repro.core import (
    DRSScheduler,
    EwmaSmoother,
    ExecutableCache,
    Machine,
    Measurer,
    Negotiator,
    RebalanceCostModel,
    ResourcePool,
    SchedulerConfig,
    StragglerDetector,
    Topology,
    WindowSmoother,
)


# --------------------------------------------------------------------- #
# Measurer
# --------------------------------------------------------------------- #
def test_ewma_smoother():
    s = EwmaSmoother(alpha=0.5)
    assert s.update(10.0) == 10.0  # first sample initialises
    assert s.update(20.0) == 15.0
    assert s.update(20.0) == 17.5


def test_window_smoother():
    s = WindowSmoother(w=3)
    s.update(1.0)
    s.update(2.0)
    assert s.update(3.0) == pytest.approx(2.0)
    assert s.update(5.0) == pytest.approx(10.0 / 3.0)  # window slid


def test_bilayer_sampling_and_rates():
    m = Measurer(["a", "b"], n_m=5, smoother="ewma", smoother_kw={"alpha": 0.0})
    pa = m.new_probe("a")
    pb1 = m.new_probe("b")
    pb2 = m.new_probe("b")  # two instances of b aggregate to operator level
    m.pull(0.0)  # establish t0
    for _ in range(100):
        pa.on_enqueue()
        pa.on_processed(service_time=0.05)
    for p in (pb1, pb2):
        for _ in range(50):
            p.on_enqueue()
            p.on_processed(service_time=0.1)
    for _ in range(100):
        m.on_external_arrival()
        m.on_tuple_complete(sojourn=0.4)
    snap = m.pull(10.0)
    assert snap.lam_hat[0] == pytest.approx(10.0)  # 100 arrivals / 10s
    assert snap.lam_hat[1] == pytest.approx(10.0)  # 2x50 aggregated
    assert snap.mu_hat[0] == pytest.approx(20.0)  # 1/0.05
    assert snap.mu_hat[1] == pytest.approx(10.0)
    assert snap.lam0_hat == pytest.approx(10.0)
    assert snap.sojourn_hat == pytest.approx(0.4)
    assert snap.complete()


def test_sampling_rate_respected():
    m = Measurer(["a"], n_m=10)
    p = m.new_probe("a")
    for _ in range(95):
        p.on_processed(0.01)
    _, processed, _, sampled, _ = p.drain()
    assert processed == 95
    assert sampled == 9  # every 10th


# --------------------------------------------------------------------- #
# Negotiator
# --------------------------------------------------------------------- #
def make_pool(n_machines=6, per=5):
    return ResourcePool([Machine(f"m{i}", per) for i in range(n_machines)])


def test_negotiator_grow_and_shrink():
    pool = make_pool()
    neg = Negotiator(pool, reserve=3)  # paper: 3 executors for spouts + DRS
    neg.ensure(22)
    assert neg.k_max >= 22
    assert len(pool.leased) == 5  # 25 executors leased, 22 usable
    neg.ensure(8)
    assert neg.k_max >= 8
    assert len(pool.leased) == 3  # 15 leased: 12 usable >= 8; 2 machines freed


def test_negotiator_revocation():
    pool = make_pool()
    changes = []
    neg = Negotiator(pool, on_change=changes.append)
    neg.ensure(20)
    lost = pool.leased[0].machine_id
    ch = neg.handle_revocation(lost)
    assert ch.delta == -5
    assert neg.k_max == 15
    assert changes  # callback fired


# --------------------------------------------------------------------- #
# Executable cache + cost model
# --------------------------------------------------------------------- #
def test_executable_cache_hit_miss_and_warm():
    compiled = []

    def fake_compile(stage, k, sig):
        compiled.append((stage, k))
        return f"exe:{stage}:{k}"

    cache = ExecutableCache(fake_compile)
    assert cache.get("prefill", 4) is None
    v = cache.get_or_compile("prefill", 4)
    assert v == "exe:prefill:4"
    assert cache.get_or_compile("prefill", 4) == v
    assert cache.hits == 1 and cache.misses >= 1
    cache.warm_neighbours("prefill", 4, radius=1)
    assert ("prefill", 3) in compiled and ("prefill", 5) in compiled


def test_rebalance_plan_cost_benefit():
    top = Topology.chain([("a", 2.0), ("b", 5.0)], lam0=5.0)
    cm = RebalanceCostModel(pause_cache_hit=0.5, pause_cache_miss=30.0)
    k_old = np.array([4, 2])
    k_new = np.array([5, 3])
    plan = cm.plan(top, k_old, k_new)
    assert plan.total_cost_seconds > 0
    assert plan.benefit_per_second > 0
    # long horizon -> worthwhile; tiny horizon -> not
    assert plan.worthwhile(3600.0, top.lam0_total)
    assert not plan.worthwhile(1e-6, top.lam0_total)


def test_rebalance_noop_never_worthwhile():
    top = Topology.chain([("a", 2.0), ("b", 5.0)], lam0=5.0)
    cm = RebalanceCostModel()
    k = np.array([4, 2])
    plan = cm.plan(top, k, k)
    assert not plan.worthwhile(1e9, top.lam0_total)


# --------------------------------------------------------------------- #
# Scheduler end-to-end (synthetic measurements)
# --------------------------------------------------------------------- #
def drive_measurements(m: Measurer, lam0, mus, routing, t0, t1, k=None):
    """Feed the measurer synthetic steady-state traffic between t0 and t1."""
    lam0_vec = np.array([lam0] + [0.0] * (len(mus) - 1))
    from repro.core.jackson import solve_traffic_equations

    lam = solve_traffic_equations(lam0_vec, routing)
    dt = t1 - t0
    probes = [m.new_probe(n) for n in m.names]
    m.pull(t0)
    for i, p in enumerate(probes):
        n_arr = int(lam[i] * dt)
        p.on_enqueue(n_arr)
        for _ in range(max(1, n_arr // m.n_m + 1)):
            for _ in range(m.n_m - 1):
                p.on_processed(0.0)  # not sampled
            p.on_processed(1.0 / mus[i])  # sampled tick
    m.on_external_arrival(int(lam0 * dt))
    m.on_tuple_complete(0.9, n=int(lam0 * dt))
    return m.pull(t1)


def chain_routing(n):
    r = np.zeros((n, n))
    for i in range(n - 1):
        r[i][i + 1] = 1.0
    return r


def test_scheduler_recommends_rebalance_toward_optimum():
    names = ["extract", "match", "agg"]
    routing = chain_routing(3)
    mus = [2.0, 5.0, 50.0]
    cfg = SchedulerConfig(k_max=22, min_improvement=0.01)
    # Start from a deliberately bad allocation.
    sched = DRSScheduler(names, routing, np.array([8, 12, 2]), cfg)
    snap = drive_measurements(sched.measurer, 13.0, mus, routing, 0.0, 60.0)
    top = sched.topology_from(snap)
    d = sched.decide(top, snap, 60.0)
    assert d.action == "rebalance"
    # The model-optimal allocation concentrates on the two heavy bolts.
    assert d.k_target is not None and d.k_target[2] <= 2
    assert d.model_sojourn_target < d.model_sojourn_current


def test_scheduler_none_when_already_optimal():
    names = ["extract", "match", "agg"]
    routing = chain_routing(3)
    mus = [2.0, 5.0, 50.0]
    cfg = SchedulerConfig(k_max=22, min_improvement=0.01)
    sched = DRSScheduler(names, routing, np.array([8, 12, 2]), cfg)
    snap = drive_measurements(sched.measurer, 13.0, mus, routing, 0.0, 60.0)
    top = sched.topology_from(snap)
    first = sched.decide(top, snap, 60.0)
    assert first.action == "rebalance"
    second = sched.decide(top, snap, 120.0)
    assert second.action == "none"  # converged in one step (Theorem 1)


def test_scheduler_scale_out_on_tmax_violation():
    """ExpA of the paper (Fig. 10): T_max unreachable at K=17 -> add machines."""
    names = ["extract", "match", "agg"]
    routing = chain_routing(3)
    mus = [2.0, 5.0, 50.0]
    pool = ResourcePool([Machine(f"m{i}", 5) for i in range(10)])
    neg = Negotiator(pool)
    neg.ensure(17)
    cfg = SchedulerConfig(t_max=0.73, min_improvement=0.01)  # tight; needs 20 > 17
    sched = DRSScheduler(names, routing, np.array([8, 8, 1]), cfg, negotiator=neg)
    snap = drive_measurements(sched.measurer, 13.0, mus, routing, 0.0, 60.0)
    top = sched.topology_from(snap)
    assert top.expected_sojourn(np.array([8, 8, 1])) > 0.73
    d = sched.decide(top, snap, 60.0)
    assert d.action == "scale_out"
    assert neg.k_max > 17
    assert top.expected_sojourn(d.k_current) <= 0.73


def test_scheduler_scale_in_when_overprovisioned():
    """ExpB of the paper: loose T_max -> release machines."""
    names = ["extract", "match", "agg"]
    routing = chain_routing(3)
    mus = [2.0, 5.0, 50.0]
    pool = ResourcePool([Machine(f"m{i}", 5) for i in range(10)])
    neg = Negotiator(pool)
    neg.ensure(40)
    cfg = SchedulerConfig(t_max=2.0, scale_in_hysteresis=0.9)
    sched = DRSScheduler(names, routing, np.array([20, 18, 2]), cfg, negotiator=neg)
    snap = drive_measurements(sched.measurer, 13.0, mus, routing, 0.0, 60.0)
    top = sched.topology_from(snap)
    d = sched.decide(top, snap, 60.0)
    assert d.action == "scale_in"
    assert neg.k_max < 40
    assert top.expected_sojourn(d.k_current) <= 2.0


def test_scheduler_tracks_datadependent_fanout():
    """More features per frame (paper §I example): lam_B rises while lam_A
    stays flat; the rebuilt topology must reflect the new multiplicity."""
    names = ["extract", "match"]
    routing = np.zeros((2, 2))
    routing[0][1] = 3.0  # declared fan-out 3 features/frame
    cfg = SchedulerConfig(k_max=20)
    sched = DRSScheduler(names, routing, np.array([10, 10]), cfg)
    m = sched.measurer
    p0, p1 = m.new_probe("extract"), m.new_probe("match")
    m.pull(0.0)
    p0.on_enqueue(130)
    p1.on_enqueue(910)  # measured fan-out is 7, not 3
    for p, st in ((p0, 0.5), (p1, 0.02)):
        for _ in range(20):
            for _ in range(m.n_m - 1):
                p.on_processed(0.0)
            p.on_processed(st)
    m.on_external_arrival(130)
    m.on_tuple_complete(1.0, 130)
    snap = m.pull(10.0)
    top = sched.topology_from(snap)
    assert top.routing[0][1] == pytest.approx(7.0, rel=0.05)
    assert top.arrival_rates[1] == pytest.approx(91.0, rel=0.05)


def test_straggler_detector():
    det = StragglerDetector(factor=2.0, window=3)
    for t in range(3):
        det.observe("match", 0, 10.0)
        det.observe("match", 1, 10.5)
        det.observe("match", 2, 2.0)  # straggler
    assert det.stragglers() == [("match", 2)]


def test_scheduler_reacts_to_straggler_mu_drop():
    """DRS-native straggler handling: mu drop -> model violation -> realloc."""
    names = ["extract", "match", "agg"]
    routing = chain_routing(3)
    cfg = SchedulerConfig(k_max=22, min_improvement=0.01)
    sched = DRSScheduler(names, routing, np.array([10, 11, 1]), cfg)
    # Healthy: mus (2, 5, 50). Straggler in 'extract' drags op mu to 1.4.
    snap = drive_measurements(sched.measurer, 13.0, [1.4, 5.0, 50.0], routing, 0.0, 60.0)
    top = sched.topology_from(snap)
    d = sched.decide(top, snap, 60.0)
    assert d.action == "rebalance"
    assert d.k_target[0] > 10  # more processors pushed to the degraded operator


def test_straggler_wired_into_decide_emits_rebalance_hint():
    """A flagged straggler instance turns a would-be 'none' tick into an
    advisory 'rebalance_hint' naming the (operator, instance)."""
    names = ["extract", "match", "agg"]
    routing = chain_routing(3)
    cfg = SchedulerConfig(k_max=22)  # default 5% improvement gate
    sched = DRSScheduler(names, routing, np.array([10, 11, 1]), cfg)
    m = sched.measurer
    # Three extract instances — instance 2 is 2.5x slower, but contributes
    # one sample so the *aggregate* mu barely moves (no model rebalance).
    probes = {n: [m.new_probe(n) for _ in range(3 if n == "extract" else 1)]
              for n in names}
    mus = {"extract": 2.0, "match": 5.0, "agg": 50.0}
    lam = {"extract": 13.0, "match": 13.0, "agg": 13.0}
    m.pull(0.0)
    for name, plist in probes.items():
        for j, p in enumerate(plist):
            p.on_enqueue(int(lam[name] * 60 / len(plist)))
            slow = name == "extract" and j == 2
            n_samples = 1 if slow else 20
            st = 2.5 / mus[name] if slow else 1.0 / mus[name]
            for _ in range(n_samples):
                for _ in range(m.n_m - 1):
                    p.on_processed(0.0)
                p.on_processed(st)
    m.on_external_arrival(int(13.0 * 60))
    m.on_tuple_complete(0.9, n=int(13.0 * 60))
    d = sched.tick(60.0)
    assert d.stragglers == (("extract", 2),)
    assert d.action == "rebalance_hint"
    assert "extract[2]" in d.reason
    # advisory only: the allocation is untouched
    np.testing.assert_array_equal(d.k_current, [10, 11, 1])


def test_no_straggler_no_hint():
    names = ["extract", "match", "agg"]
    routing = chain_routing(3)
    cfg = SchedulerConfig(k_max=22)
    sched = DRSScheduler(names, routing, np.array([10, 11, 1]), cfg)
    snap = drive_measurements(sched.measurer, 13.0, [2.0, 5.0, 50.0], routing, 0.0, 60.0)
    sched._observe_instances()
    top = sched.topology_from(snap)
    d = sched.decide(top, snap, 60.0)
    assert d.action == "none"
    assert d.stragglers == ()


def test_straggler_detector_can_be_disabled():
    names = ["a"]
    cfg = SchedulerConfig(k_max=4)
    sched = DRSScheduler(names, np.zeros((1, 1)), np.array([2]), cfg,
                         straggler_detector=None)
    # default detector is constructed when None is passed
    assert sched.straggler_detector is not None
    sched.straggler_detector = None
    assert sched.straggler_hints() == ()
