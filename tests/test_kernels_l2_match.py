"""l2_match Pallas kernel vs pure-jnp oracle (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no dev deps installed — deterministic fallback sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.l2_match import kernel, ops, ref


def rand(shape, dtype, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape)
    return x.astype(dtype)


@pytest.mark.parametrize("m,n,d", [(128, 128, 64), (256, 128, 64), (128, 256, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_kernel_matches_ref(m, n, d, dtype):
    a, b = rand((m, d), dtype, 0), rand((n, d), dtype, 1)
    got = kernel.pairwise_sq_l2_pallas(a, b, interpret=True)
    want = ref.pairwise_sq_l2(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("bm,bn", [(64, 64), (128, 64)])
def test_block_shapes_same_result(bm, bn):
    a, b = rand((128, 48), jnp.float32, 2), rand((128, 48), jnp.float32, 3)
    got = kernel.pairwise_sq_l2_pallas(a, b, bm=bm, bn=bn, interpret=True)
    want = ref.pairwise_sq_l2(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_count_kernel_matches_ref():
    a, b = rand((256, 64), jnp.float32, 4), rand((128, 64), jnp.float32, 5)
    valid = jnp.arange(256) % 3 != 0  # some invalid rows
    thresh = 9.0
    got = kernel.match_count_pallas(a, b, valid, thresh, interpret=True)
    want = ref.match_count(a, b, thresh, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(got.sum()) > 0  # the threshold actually fires


def test_fused_count_accumulates_across_m_blocks():
    # m = 4 blocks of 64: accumulation across sequential grid steps.
    a, b = rand((256, 32), jnp.float32, 6), rand((64, 32), jnp.float32, 7)
    valid = jnp.ones(256, dtype=bool)
    got = kernel.match_count_pallas(a, b, valid, 8.0, bm=64, bn=64, interpret=True)
    want = ref.match_count(a, b, 8.0, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    m=st.integers(min_value=1, max_value=200),
    n=st.integers(min_value=1, max_value=200),
    d=st.integers(min_value=1, max_value=96),
)
@settings(max_examples=20, deadline=None)
def test_ops_wrapper_pads_arbitrary_shapes(m, n, d):
    """ops-level dispatch handles non-multiple shapes via padding."""
    ops.set_mode("kernel_interpret")
    try:
        a, b = rand((m, d), jnp.float32, m * 7 + 1), rand((n, d), jnp.float32, n * 13 + 2)
        got = ops.pairwise_sq_l2(a, b, bm=64, bn=64)
        want = ref.pairwise_sq_l2(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    finally:
        ops.set_mode("auto")


def test_ops_match_count_padded():
    ops.set_mode("kernel_interpret")
    try:
        a, b = rand((100, 50), jnp.float32, 8), rand((70, 50), jnp.float32, 9)
        got = ops.match_count(a, b, 7.5)
        want = ref.match_count(a, b, 7.5)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    finally:
        ops.set_mode("auto")


def test_padding_rows_do_not_pollute_counts():
    """Padded (zero) query rows must not count as matches even when the
    library contains a zero-ish row within threshold of zero."""
    ops.set_mode("kernel_interpret")
    try:
        a = jnp.ones((3, 16))  # pads to 64 rows of zeros
        b = jnp.zeros((2, 16))  # zero library rows: d2(pad, b) == 0 <= t2
        got = ops.match_count(a, b, 1.0, bm=64, bn=64)
        want = ref.match_count(a, b, 1.0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    finally:
        ops.set_mode("auto")


def test_ref_zero_distance_diagonal():
    a = rand((32, 16), jnp.float32, 10)
    d2 = ref.pairwise_sq_l2(a, a)
    assert float(jnp.abs(jnp.diagonal(d2)).max()) < 1e-4
    assert float(d2.min()) >= 0.0
