"""The mesh-sharded control plane (DESIGN.md §16).

Two tiers of tests:

* **Single-device** (always run): pad-and-mask semantics — padded
  statics/params/arrays, the masked-lane "none" contract on both the
  jit decide and the numpy twin, the chunked/donated
  :class:`~repro.core.controller.FusedLoop` carry.
* **Multi-device** (skipped unless >= 2 devices are visible): bit-identity
  of the sharded fused loop / decide / planner against the unsharded
  program.  Run locally or in CI with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the flag must
  be set *before* jax imports.

Bit-identity contract: decisions and allocations (action codes, k, the
applied mask, integer aggregates) are compared **bitwise**; the E[T]
diagnostics (``et_cur``/``et_target``/``sojourn``) get an rtol because
XLA may reassociate float32 lane reductions differently at different
batch extents (~1 ulp).
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

import repro.core.controller as ctl
from repro.api.session import ScenarioRunner
from repro.core.measurer import MeasurementBatch
from repro.distributed.sharding import fleet_mesh
from repro.streaming.scenarios import pack_scenarios, scenario_matrix

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices: XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

# Bitwise-equal keys (decisions, allocations, integer aggregates) vs
# rtol'd float diagnostics — see module docstring.
EXACT = (
    "codes", "k", "applied", "miss", "warm_windows", "k_final", "q_final",
    "offered", "served", "dropped", "ext_admitted", "ext_offered",
    "q_int", "q_max", "mpc_used", "confident",
)
CLOSE = ("sojourn", "et_cur", "et_target")


def _scens(b, seed=11):
    return [
        s.with_(negotiated=False)
        for s in scenario_matrix(b, seed=seed, horizon=20.0, warmup=5.0, dt=0.05)
    ]


def _mpc_cfg():
    from repro.forecast.mpc import MPCConfig, PredictorParams

    return MPCConfig(horizon=3, window=12, min_scored=2,
                     predictor=PredictorParams(kind="holt", alpha=0.6, beta=0.4))


def _loop(scens, mesh=None, proactive=None, compact=None):
    r = ScenarioRunner(scens, tick_interval=5.0, backend="jax",
                       mesh=mesh, proactive=proactive, compact=compact)
    assert r.fused
    loop, n_ticks = ctl.make_fused_loop(
        r.arrays, r.static, r._params(),
        steps_per_tick=r._steps_per_tick,
        warmup_seconds=scens[0].warmup,
        proactive=r.proactive_cfg, mesh=mesh, compact=compact,
    )
    return r, loop, n_ticks


def _assert_outs_match(ref: dict, got: dict):
    assert set(ref) == set(got)
    for key in ref:
        a, b = np.asarray(ref[key]), np.asarray(got[key])
        if key in EXACT:
            np.testing.assert_array_equal(a, b, err_msg=key)
        else:
            assert key in CLOSE, key
            np.testing.assert_allclose(a, b, rtol=1e-6, err_msg=key)


# --------------------------------------------------------------------------- #
# Pad-and-mask semantics (single device)
# --------------------------------------------------------------------------- #
def test_pad_static_params_build_inert_lanes():
    scens = _scens(3)
    r = ScenarioRunner(scens, tick_interval=5.0, backend="jax")
    st = ctl.pad_static(r.static, 5)
    pr = ctl.pad_params(r._params(), 5)
    assert st.batch == 5 and pr.k_max.shape[0] == 5
    # inert lane contract: no operators, no routing, no budget, closed gates
    assert (st.n_ops[3:] == 0).all()
    assert not st.active[3:].any()
    assert (st.base_routing[3:] == 0).all()
    assert (st.speed[3:] == 1.0).all()
    assert (pr.k_max[3:] == 0).all()
    assert np.isnan(pr.t_max[3:]).all()
    assert np.isinf(pr.min_improvement[3:]).all()
    # idempotent at the same extent, refuses to shrink
    assert ctl.pad_static(st, 5) is st
    with pytest.raises(ValueError):
        ctl.pad_static(st, 4)
    with pytest.raises(ValueError):
        ctl.pad_params(pr, 4)


def test_pack_scenarios_pad_to_inert_arrivals():
    scens = _scens(3)
    base = pack_scenarios(scens)
    padded = pack_scenarios(scens, pad_to=5)
    assert padded.batch == 5
    assert (np.asarray(padded.ext)[:, 3:, :] == 0).all()
    np.testing.assert_array_equal(np.asarray(padded.ext)[:, :3], np.asarray(base.ext))
    assert not padded.active[3:].any()
    with pytest.raises(ValueError):
        pack_scenarios(scens, pad_to=2)


def test_masked_lanes_decide_none_in_jit_and_twin():
    """Satellite contract: a padded lane decides "none" bit-for-bit, with
    an unchanged (all-zero) allocation — in the jit decide AND the twin."""
    scens = _scens(3)
    r = ScenarioRunner(scens, tick_interval=5.0, backend="jax")
    b, n = 5, r.static.n
    st = ctl.pad_static(r.static, b)
    pr = ctl.pad_params(r._params(), b)
    rng = np.random.default_rng(0)
    lam = np.abs(rng.normal(2.0, 0.5, (b, n)))
    mu = np.abs(rng.normal(6.0, 0.5, (b, n))) + 1.0
    drop = np.zeros((b, n))
    lam0 = np.abs(rng.normal(2.0, 0.5, b))
    k = np.where(st.active, 2, 0).astype(np.int64)

    decide = ctl.make_decide_jax(st, pr)
    code, k_next, et_cur, et_target, applied = (
        np.asarray(v) for v in decide(lam, mu, drop, lam0, k)
    )
    none_code = ctl.ACTIONS.index("none")
    np.testing.assert_array_equal(code[3:], none_code)
    np.testing.assert_array_equal(applied[3:], False)
    np.testing.assert_array_equal(k_next[3:], k[3:])  # allocation untouched

    meas = MeasurementBatch(
        lam_hat=lam, mu_hat=mu, lam0_hat=lam0,
        sojourn_hat=np.full(b, 0.5), t=0.0, drop_hat=drop,
    )
    batch = ctl.tick_batch(meas, k.copy(), st, pr)
    for row in batch.rows[3:]:
        assert row.action == "none" and not row.applied
        assert row.reason == "padded lane"


def test_fused_loop_padded_lanes_never_influence_real_ones():
    """Run the fused loop at B and at B+2 (two inert pad lanes, no mesh):
    the real lanes' decisions and aggregates must be bitwise unchanged,
    and the pad lanes must decide "none" forever with zero aggregates."""
    scens = _scens(4, seed=7)
    _, loop, _ = _loop(scens)
    ref = {k: np.asarray(v) for k, v in loop(
        ScenarioRunner(scens, tick_interval=5.0, backend="jax").k).items()}

    r = ScenarioRunner(scens, tick_interval=5.0, backend="jax")
    b_pad = len(scens) + 2
    arrays = pack_scenarios(scens, pad_to=b_pad)
    loop_p, _ = ctl.make_fused_loop(
        arrays, ctl.pad_static(r.static, b_pad), ctl.pad_params(r._params(), b_pad),
        steps_per_tick=r._steps_per_tick, warmup_seconds=scens[0].warmup,
    )
    k0 = np.zeros((b_pad, r.static.n), dtype=np.int64)
    k0[: len(scens)] = r.k
    got = {k: np.asarray(v) for k, v in loop_p(k0).items()}

    none_code = ctl.ACTIONS.index("none")

    # slice real lanes per key shape: batch is the last-but-one axis for
    # [T, B, N] / [B, N] arrays and the last axis for [T, B] / [B] ones.
    def real_lanes(v):
        if v.ndim >= 2 and v.shape[-2] == b_pad:
            return v[..., : len(scens), :]
        if v.ndim >= 1 and v.shape[-1] == b_pad:
            return v[..., : len(scens)]
        return v

    def pad_lanes(v):
        if v.ndim >= 2 and v.shape[-2] == b_pad:
            return v[..., len(scens):, :]
        if v.ndim >= 1 and v.shape[-1] == b_pad:
            return v[..., len(scens):]
        return None

    for key in EXACT:
        if key not in ref:
            continue
        np.testing.assert_array_equal(real_lanes(got[key]), ref[key], err_msg=key)
    for key in CLOSE:
        np.testing.assert_allclose(
            real_lanes(got[key]), ref[key], rtol=1e-6, err_msg=key
        )
    np.testing.assert_array_equal(pad_lanes(got["codes"]), none_code)
    np.testing.assert_array_equal(pad_lanes(got["applied"]), False)
    for key in ("k_final", "q_final", "offered", "served", "dropped",
                "q_int", "q_max", "miss"):
        np.testing.assert_array_equal(pad_lanes(got[key]), 0, err_msg=key)


# --------------------------------------------------------------------------- #
# Chunked, donated carry (single device)
# --------------------------------------------------------------------------- #
def test_fused_loop_chunked_resume_bit_identical():
    scens = _scens(4, seed=3)
    r, loop, n_ticks = _loop(scens)
    ref = {k: np.asarray(v) for k, v in loop(r.k).items()}

    r2, loop2, _ = _loop(scens)
    state = loop2.init(r2.k)
    state, out_a = loop2.run(state, 2)
    state, out_b = loop2.run(state)  # remainder of the horizon
    assert int(state.tick) == n_ticks
    for key in ("codes", "k", "sojourn", "et_cur", "et_target", "applied"):
        merged = np.concatenate([np.asarray(out_a[key]), np.asarray(out_b[key])])
        np.testing.assert_array_equal(merged, ref[key], err_msg=key)
    # run aggregates carried in the state: the final chunk's dict has them
    for key in ("k_final", "q_final", "offered", "served", "dropped",
                "ext_admitted", "ext_offered", "q_int", "q_max"):
        np.testing.assert_array_equal(
            np.asarray(out_b[key]), ref[key], err_msg=key
        )
    # miss / warm_windows are per-chunk sums
    np.testing.assert_array_equal(
        np.asarray(out_a["miss"]) + np.asarray(out_b["miss"]), ref["miss"]
    )
    assert int(out_a["warm_windows"]) + int(out_b["warm_windows"]) == int(
        ref["warm_windows"]
    )


def test_fused_loop_run_donates_the_carry():
    scens = _scens(3, seed=5)
    r, loop, _ = _loop(scens)
    state = loop.init(r.k)
    new_state, _ = loop.run(state, 1)
    # donate_argnums=0: the old carry's buffers are consumed by XLA
    assert state.q.is_deleted()
    assert state.k.is_deleted()
    assert not new_state.q.is_deleted()


def test_fused_loop_run_range_validation():
    scens = _scens(2, seed=9)
    r, loop, n_ticks = _loop(scens)
    state = loop.init(r.k)
    with pytest.raises(ValueError):
        loop.run(state, 0)
    with pytest.raises(ValueError):
        loop.run(state, n_ticks + 1)
    state, _ = loop.run(state, n_ticks)
    with pytest.raises(ValueError):
        loop.run(state, 1)  # horizon exhausted


# --------------------------------------------------------------------------- #
# Sharded vs unsharded bit-identity (multi device)
# --------------------------------------------------------------------------- #
@multi_device
def test_sharded_fused_loop_bit_identical_to_unsharded():
    scens = _scens(8, seed=21)
    r, loop, _ = _loop(scens)
    ref = {k: np.asarray(v) for k, v in loop(r.k).items()}
    mesh = fleet_mesh(2)
    rm, loop_m, _ = _loop(scens, mesh=mesh)
    got = {k: np.asarray(v) for k, v in loop_m(rm.k).items()}
    _assert_outs_match(ref, got)


@multi_device
def test_sharded_fused_loop_nondivisible_batch():
    """B = 6 on a 4-device mesh: two lanes of shard padding, decisions
    still bit-identical to the unsharded program."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices")
    scens = _scens(6, seed=17)
    r, loop, _ = _loop(scens)
    ref = {k: np.asarray(v) for k, v in loop(r.k).items()}
    rm, loop_m, _ = _loop(scens, mesh=fleet_mesh(4))
    got = {k: np.asarray(v) for k, v in loop_m(rm.k).items()}
    _assert_outs_match(ref, got)


@multi_device
def test_sharded_proactive_fused_loop_bit_identical():
    scens = _scens(8, seed=29)
    cfg = _mpc_cfg()
    r, loop, _ = _loop(scens, proactive=cfg)
    ref = {k: np.asarray(v) for k, v in loop(r.k).items()}
    rm, loop_m, _ = _loop(scens, mesh=fleet_mesh(2), proactive=cfg)
    got = {k: np.asarray(v) for k, v in loop_m(rm.k).items()}
    _assert_outs_match(ref, got)


@multi_device
def test_sharded_chunked_resume_bit_identical():
    scens = _scens(8, seed=31)
    r, loop, n_ticks = _loop(scens)
    ref = {k: np.asarray(v) for k, v in loop(r.k).items()}
    rm, loop_m, _ = _loop(scens, mesh=fleet_mesh(2))
    state = loop_m.init(rm.k)
    state, out_a = loop_m.run(state, 1)
    state, out_b = loop_m.run(state)
    merged = np.concatenate([np.asarray(out_a["codes"]), np.asarray(out_b["codes"])])
    np.testing.assert_array_equal(merged, ref["codes"])
    np.testing.assert_array_equal(np.asarray(out_b["k_final"]), ref["k_final"])


@multi_device
def test_sharded_compacted_fused_loop_bit_identical_to_dense_unsharded():
    """§18 per-shard compaction under shard_map: each device compacts its
    own lanes (no cross-device gather), and the whole loop still matches
    the dense unsharded program — decisions bitwise, E[T] to rtol."""
    scens = _scens(8, seed=21)
    r, loop, _ = _loop(scens)
    ref = {k: np.asarray(v) for k, v in loop(r.k).items()}
    rm, loop_m, _ = _loop(scens, mesh=fleet_mesh(2), compact=True)
    got = {k: np.asarray(v) for k, v in loop_m(rm.k).items()}
    assert got.pop("repriced").shape == ref["codes"].shape
    _assert_outs_match(ref, got)


@multi_device
def test_sharded_compacted_nondivisible_batch():
    """B = 6 on a 4-device mesh with compaction on: the shard-padding
    lanes ride the trigger scan as permanently-quiet lanes."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices")
    scens = _scens(6, seed=17)
    r, loop, _ = _loop(scens)
    ref = {k: np.asarray(v) for k, v in loop(r.k).items()}
    rm, loop_m, _ = _loop(scens, mesh=fleet_mesh(4), compact=True)
    got = {k: np.asarray(v) for k, v in loop_m(rm.k).items()}
    got.pop("repriced")
    _assert_outs_match(ref, got)


@multi_device
def test_make_decide_jax_mesh_compact_parity():
    """The standalone mesh compacted decide: bit-identical decisions to
    the dense unsharded decide across cold / quiet / perturbed ticks,
    with per-shard trigger counts summing to the expected totals."""
    scens = _scens(8, seed=13)
    r = ScenarioRunner(scens, tick_interval=5.0, backend="jax")
    b, n = len(scens), r.static.n
    rng = np.random.default_rng(2)
    lam = np.abs(rng.normal(2.0, 0.6, (b, n)))
    mu = np.abs(rng.normal(6.0, 0.5, (b, n))) + 1.0
    drop = np.zeros((b, n))
    lam0 = np.abs(rng.normal(2.0, 0.5, b))
    k = np.where(r.static.active, 2, 0).astype(np.int64)

    dense = ctl.make_decide_jax(r.static, r._params())
    comp = ctl.make_decide_jax(
        r.static, r._params(), mesh=fleet_mesh(2), compact=True
    )
    cache = comp.init_cache()

    def check(lam_t):
        want = dense(lam_t, mu, drop, lam0, k)
        nonlocal cache
        got, repriced, cache = comp(lam_t, mu, drop, lam0, k, cache)
        for name, a, b_ in zip(
            ("code", "k_next", "et_cur", "et_target", "applied"), want, got
        ):
            a, b_ = np.asarray(a), np.asarray(b_)
            if name in ("et_cur", "et_target"):
                np.testing.assert_allclose(a, b_, rtol=1e-6, err_msg=name)
            else:
                np.testing.assert_array_equal(a, b_, err_msg=name)
        return int(np.asarray(repriced)[:b].sum())

    assert check(lam) == b  # cold
    assert check(lam) == 0  # quiet
    lam2 = lam.copy()
    lam2[3] *= 1.5
    assert check(lam2) == 1  # exactly the perturbed lane, on its shard
    assert check(lam2) == 0


@multi_device
def test_make_decide_jax_mesh_parity():
    scens = _scens(8, seed=13)
    r = ScenarioRunner(scens, tick_interval=5.0, backend="jax")
    b, n = len(scens), r.static.n
    rng = np.random.default_rng(2)
    lam = np.abs(rng.normal(2.0, 0.6, (b, n)))
    mu = np.abs(rng.normal(6.0, 0.5, (b, n))) + 1.0
    drop = np.zeros((b, n))
    lam0 = np.abs(rng.normal(2.0, 0.5, b))
    k = np.where(r.static.active, 2, 0).astype(np.int64)

    ref = ctl.make_decide_jax(r.static, r._params())(lam, mu, drop, lam0, k)
    got = ctl.make_decide_jax(r.static, r._params(), mesh=fleet_mesh(2))(
        lam, mu, drop, lam0, k
    )
    for name, a, b_ in zip(("code", "k_next", "et_cur", "et_target", "applied"),
                           ref, got):
        a, b_ = np.asarray(a), np.asarray(b_)
        if name in ("et_cur", "et_target"):
            np.testing.assert_allclose(a, b_, rtol=1e-6, err_msg=name)
        else:
            np.testing.assert_array_equal(a, b_, err_msg=name)


@multi_device
def test_scenario_runner_mesh_reports_match():
    scens = _scens(6, seed=23)
    base = ScenarioRunner(scens, tick_interval=5.0, backend="jax").run()
    mesh = ScenarioRunner(
        _scens(6, seed=23), tick_interval=5.0, backend="jax",
        mesh=fleet_mesh(len(jax.devices())),
    ).run()
    for rb, rm in zip(base, mesh):
        assert list(rb.actions) == list(rm.actions)
        assert rb.k_final == rm.k_final
        assert rb.trajectory["k_total"] == rm.trajectory["k_total"]
        assert rb.trajectory["miss"] == rm.trajectory["miss"]


def test_controller_mesh_must_be_1d():
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    from jax.sharding import Mesh

    with pytest.raises(ValueError):
        ctl._mesh_axis(Mesh(devs, ("a", "b")))
