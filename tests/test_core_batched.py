"""Batched analytic core (core/batched.py + kernels/erlang_c) vs the scalar
model — the DESIGN.md §12 agreement guarantees."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batched import (
    expected_sojourn_batch,
    expected_sojourn_batch_jax,
    gain_table,
    sojourn_from_table,
    sojourn_table,
    sojourn_table_jax,
    solve_traffic_batch,
    solve_traffic_batch_jax,
)
from repro.core.erlang import marginal_benefit
from repro.core.jackson import OperatorSpec, Topology, solve_traffic_equations
from repro.kernels.erlang_c import kernel as ek, ref as eref


def vld_top(lam0=13.0):
    return Topology.chain(
        [("extract", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=lam0
    )


def mixed_top():
    """Replica + chip-group scaling + a zero-traffic operator."""
    ops = [
        OperatorSpec("gang", 3.0, scaling="group", group_alpha=0.05),
        OperatorSpec("rep", 10.0),
        OperatorSpec("idle", 4.0),  # no traffic routed here
    ]
    routing = np.zeros((3, 3))
    routing[0][1] = 1.0
    return Topology(ops, np.array([8.0, 0.0, 0.0]), routing)


# ------------------------------------------------------------------ #
# numpy table vs scalar: bit-exact
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("top", [vld_top(), mixed_top()], ids=["vld", "mixed"])
def test_sojourn_table_bit_identical_to_scalar(top):
    k_hi = 64
    T = sojourn_table(top, k_hi)
    lam = top.arrival_rates
    for i, op in enumerate(top.operators):
        for k in range(k_hi + 1):
            want = op.sojourn(k, lam[i])
            got = T[i, k]
            assert np.isinf(want) == np.isinf(got), (i, k)
            if np.isfinite(want):
                assert got == want, (i, k, got, want)  # bit-identical, not approx


def test_sojourn_table_wide_operator_set_vectorized_path():
    """> 64 operators takes the vectorized recursion branch — must still
    match the scalar model bit-for-bit."""
    n = 80
    ops = [OperatorSpec(f"o{i}", 2.0 + 0.1 * i) for i in range(n)]
    routing = np.zeros((n, n))
    for i in range(n - 1):
        routing[i][i + 1] = 0.9
    top = Topology(ops, np.r_[40.0, np.zeros(n - 1)], routing)
    T = sojourn_table(top, 48)
    lam = top.arrival_rates
    for i in (0, 1, 37, 79):
        op = top.operators[i]
        for k in range(49):
            want = op.sojourn(k, lam[i])
            assert (np.isinf(want) and np.isinf(T[i, k])) or T[i, k] == want


def test_gain_table_matches_marginal_benefit():
    top = vld_top()
    lam = top.arrival_rates
    _, G = gain_table(top, 40)
    for i, op in enumerate(top.operators):
        for k in range(1, 40):
            want = marginal_benefit(k, lam[i], op.mu)
            if np.isinf(want):
                assert np.isinf(G[i, k])
            else:
                assert G[i, k] == want


def test_batch_sojourn_agrees_with_topology_to_1e9():
    top = vld_top()
    rng = np.random.default_rng(0)
    k_min = top.min_feasible_allocation()
    K = k_min[None, :] + rng.integers(0, 12, size=(32, top.n))
    e = expected_sojourn_batch(top, K)
    for r in range(K.shape[0]):
        assert e[r] == pytest.approx(top.expected_sojourn(K[r]), abs=1e-9)


def test_batch_sojourn_infeasible_rows_are_inf():
    top = vld_top()
    K = np.array([[1, 1, 1], [8, 3, 1]])  # row 0 unstable (extract needs 7)
    e = expected_sojourn_batch(top, K)
    assert np.isinf(e[0]) and np.isfinite(e[1])


def test_sojourn_from_table_shapes():
    top = vld_top()
    T = sojourn_table(top, 16)
    per_op, e2e = sojourn_from_table(
        T, np.array([8, 4, 1]), top.arrival_rates, top.lam0_total
    )
    assert per_op.shape == (3,) and np.isscalar(float(e2e))


# ------------------------------------------------------------------ #
# traffic-equation batches
# ------------------------------------------------------------------ #
def test_traffic_batch_matches_scalar_solver():
    top = mixed_top()
    scales = np.array([0.25, 1.0, 3.5])
    lam0_b = scales[:, None] * top.lam0[None, :]
    got = solve_traffic_batch(lam0_b, top.routing)
    for r, s in enumerate(scales):
        want = solve_traffic_equations(s * top.lam0, top.routing)
        np.testing.assert_allclose(got[r], want, atol=1e-9)


def test_traffic_batch_per_scenario_routing():
    top = vld_top()
    p = np.stack([top.routing, 2.0 * top.routing * 0.45])
    lam0_b = np.stack([top.lam0, top.lam0])
    got = solve_traffic_batch(lam0_b, p)
    for r in range(2):
        want = solve_traffic_equations(lam0_b[r], p[r])
        np.testing.assert_allclose(got[r], want, atol=1e-9)


def test_traffic_batch_rejects_bad_routing_shape():
    with pytest.raises(ValueError):
        solve_traffic_batch(np.ones((2, 3)), np.ones((4, 4)))


# ------------------------------------------------------------------ #
# jnp path — vmap/jit-able twin; x64 hits 1e-9, f32 stays loose
# ------------------------------------------------------------------ #
def test_jax_table_agrees_f32():
    top = vld_top()
    T = sojourn_table(top, 40)
    Tj = np.asarray(
        sojourn_table_jax(top.arrival_rates, np.array([2.0, 5.0, 50.0]), k_hi=40)
    )
    assert (np.isinf(T) == np.isinf(Tj)).all()
    m = np.isfinite(T)
    np.testing.assert_allclose(Tj[m], T[m], rtol=1e-5)


def test_jax_table_agrees_1e9_under_x64():
    top = vld_top()
    T = sojourn_table(top, 40)
    with jax.experimental.enable_x64():
        Tj = np.asarray(
            sojourn_table_jax(
                jnp.asarray(top.arrival_rates), jnp.asarray([2.0, 5.0, 50.0]), k_hi=40
            )
        )
    m = np.isfinite(T)
    np.testing.assert_allclose(Tj[m], T[m], atol=1e-9)


def test_jax_batch_sojourn_and_traffic():
    top = vld_top()
    K = np.array([[8, 4, 1], [9, 5, 1], [12, 7, 2]])
    ej = np.asarray(expected_sojourn_batch_jax(top, K))
    en = expected_sojourn_batch(top, K)
    np.testing.assert_allclose(ej, en, rtol=1e-5)
    lam0_b = np.stack([top.lam0, 2 * top.lam0])
    tj = np.asarray(solve_traffic_batch_jax(lam0_b, top.routing))
    np.testing.assert_allclose(tj, solve_traffic_batch(lam0_b, top.routing), rtol=1e-5)


def test_jax_table_is_vmappable():
    """Batch of tenant arrival vectors through one vmapped table build."""
    mus = jnp.asarray([2.0, 5.0, 50.0])
    lams = jnp.asarray([[13.0, 13.0, 13.0], [6.0, 6.0, 6.0]])
    fn = jax.vmap(lambda lam: sojourn_table_jax(lam, mus, k_hi=16))
    out = np.asarray(fn(lams))
    assert out.shape == (2, 3, 17)
    single = np.asarray(sojourn_table_jax(lams[1], mus, k_hi=16))
    m = np.isfinite(single)
    np.testing.assert_allclose(out[1][m], single[m], rtol=1e-6)


# ------------------------------------------------------------------ #
# Pallas kernel (interpret mode on CPU) vs the scan oracle
# ------------------------------------------------------------------ #
def test_erlang_b_kernel_interpret_matches_ref():
    a = jnp.asarray(np.linspace(0.1, 40.0, 7), dtype=jnp.float32)
    got = ek.erlang_b_table_pallas(a, k_hi=50, interpret=True)
    want = eref.erlang_b_table(a, k_hi=50)
    assert got.shape == (51, 7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7)


def test_erlang_b_kernel_lane_padding():
    a = jnp.asarray(np.linspace(0.5, 10.0, 130), dtype=jnp.float32)  # > 1 lane row
    got = ek.erlang_b_table_pallas(a, k_hi=12, interpret=True)
    want = eref.erlang_b_table(a, k_hi=12)
    assert got.shape == (13, 130)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7)


def test_erlang_b_ref_matches_scalar_recursion():
    from repro.core.erlang import erlang_b

    a = jnp.asarray([0.5, 3.0, 9.5])
    tab = np.asarray(eref.erlang_b_table(a, k_hi=30))
    for i, ai in enumerate([0.5, 3.0, 9.5]):
        for k in (0, 1, 7, 30):
            assert tab[k, i] == pytest.approx(erlang_b(k, ai), rel=1e-5)
