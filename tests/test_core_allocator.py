"""Tests for Algorithm 1 / Programs (4) and (6) — incl. Theorem 1."""

import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no dev deps installed — deterministic fallback sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.allocator import (
    InsufficientResourcesError,
    allocate,
    assign_processors,
    assign_processors_naive,
    brute_force_optimal,
    min_processors,
)
from repro.core.jackson import OperatorSpec, Topology


def vld_like(lam0=13.0, mus=(2.0, 5.0, 50.0)):
    return Topology.chain(
        [("extract", mus[0]), ("match", mus[1]), ("agg", mus[2])], lam0=lam0
    )


def test_insufficient_resources_raises():
    top = vld_like()
    k_min = int(top.min_feasible_allocation().sum())
    with pytest.raises(InsufficientResourcesError):
        assign_processors(top, k_min - 1)


def test_heap_matches_naive_reference():
    top = vld_like()
    for k_max in range(11, 30):
        a = assign_processors(top, k_max)
        b = assign_processors_naive(top, k_max)
        assert a.expected_sojourn == pytest.approx(b.expected_sojourn, rel=1e-12)
        np.testing.assert_array_equal(a.k, b.k)


def test_theorem1_optimality_vs_brute_force():
    """Theorem 1: Algorithm 1 returns the exact optimum of Program (4)."""
    top = vld_like()
    for k_max in [11, 13, 16, 20, 22]:
        greedy = assign_processors(top, k_max)
        _, best_t = brute_force_optimal(top, k_max)
        assert greedy.expected_sojourn == pytest.approx(best_t, rel=1e-12)


def test_theorem1_on_loop_topology():
    ops = [OperatorSpec("gen", 4.0), OperatorSpec("det", 3.0), OperatorSpec("rep", 30.0)]
    routing = np.zeros((3, 3))
    routing[0][1] = 2.0
    routing[1][1] = 0.3
    routing[1][2] = 0.7
    top = Topology(ops, np.array([5.0, 0, 0]), routing)
    for k_max in [10, 12, 15]:
        greedy = assign_processors(top, k_max)
        _, best_t = brute_force_optimal(top, k_max)
        assert greedy.expected_sojourn == pytest.approx(best_t, rel=1e-12)


@given(
    lam0=st.floats(min_value=1.0, max_value=20.0),
    mu1=st.floats(min_value=0.5, max_value=10.0),
    mu2=st.floats(min_value=0.5, max_value=10.0),
    extra=st.integers(min_value=0, max_value=8),
)
@settings(max_examples=60, deadline=None)
def test_theorem1_property(lam0, mu1, mu2, extra):
    top = Topology.chain([("a", mu1), ("b", mu2)], lam0=lam0)
    k_min = int(top.min_feasible_allocation().sum())
    k_max = k_min + extra
    greedy = assign_processors(top, k_max)
    _, best_t = brute_force_optimal(top, k_max)
    if math.isfinite(best_t):
        assert greedy.expected_sojourn == pytest.approx(best_t, rel=1e-9)


def test_budget_fully_used_when_beneficial():
    top = vld_like()
    res = assign_processors(top, 22)
    assert res.total == 22  # every extra processor still reduces E[T]


def test_paper_style_allocation_shape():
    """Qualitative check mirroring the paper's VLD result (10:11:1):
    the bottleneck operators get nearly all processors; the cheap
    aggregator gets the minimum."""
    top = vld_like()
    res = assign_processors(top, 22)
    k = res.k
    assert k[2] <= 2  # aggregator is 50 tup/s: one or two processors suffice
    assert k[0] + k[1] >= 20


def test_program6_meets_tmax_minimally():
    top = vld_like()
    t_max = 1.2
    res = min_processors(top, t_max)
    assert res.expected_sojourn <= t_max
    # Dropping any single processor (where feasible) must violate T_max:
    k_min = top.min_feasible_allocation()
    for i in range(top.n):
        if res.k[i] > k_min[i]:
            k2 = res.k.copy()
            k2[i] -= 1
            assert top.expected_sojourn(k2) > t_max


def test_program6_unreachable_tmax_raises():
    top = vld_like()
    # Service-time floor = 1/2 + 1/5 + 1/50 = 0.72; below it -> infeasible.
    with pytest.raises(InsufficientResourcesError):
        min_processors(top, 0.5)


def test_program6_floor_is_tight():
    top = vld_like()
    res = min_processors(top, 0.75)  # just above the 0.72 floor
    assert res.expected_sojourn <= 0.75


def test_allocate_dispatch():
    top = vld_like()
    r4 = allocate(top, k_max=22)
    assert r4.total == 22
    r6 = allocate(top, t_max=1.2)
    assert r6.expected_sojourn <= 1.2
    # both: Program 6 result fits within k_max -> returned as-is
    r_both = allocate(top, k_max=50, t_max=1.2)
    assert r_both.total == r6.total
    # both, but budget binds -> falls back to Program 4 at k_max
    k_min = int(top.min_feasible_allocation().sum())
    r_tight = allocate(top, k_max=k_min + 1, t_max=1e-9)
    assert r_tight.total == k_min + 1


def test_evaluation_count_heap_beats_naive():
    """The heap allocator's O((K-K0) log N) work: far fewer evaluations."""
    ops = [OperatorSpec(f"op{i}", 2.0 + 0.3 * i) for i in range(12)]
    routing = np.zeros((12, 12))
    for i in range(11):
        routing[i][i + 1] = 1.0
    top = Topology(ops, np.array([5.0] + [0.0] * 11), routing)
    naive = assign_processors_naive(top, 120)
    heap = assign_processors(top, 120)
    np.testing.assert_array_equal(naive.k, heap.k)
    assert heap.evaluations < naive.evaluations / 3


# --------------------------------------------------------------------- #
# Gain-table allocator parity (DESIGN.md §12): heap-greedy, table-greedy,
# and the naive Algorithm-1 oracle must agree — bit-identically — for both
# scaling modes, through K_max = 512.
# --------------------------------------------------------------------- #
from repro.core.allocator import (  # noqa: E402
    assign_processors_table,
    greedy_increments,
    min_processors_table,
)
from repro.core.batched import gain_table  # noqa: E402


def group_like(lam0=8.0):
    """Chip-gang stage feeding a replica stage feeding a light reporter."""
    ops = [
        OperatorSpec("gang", 3.0, scaling="group", group_alpha=0.05),
        OperatorSpec("rep", 6.0),
        OperatorSpec("report", 30.0),
    ]
    routing = np.zeros((3, 3))
    routing[0][1] = 1.0
    routing[1][2] = 0.7
    return Topology(ops, np.array([lam0, 0.0, 0.0]), routing)


@pytest.mark.parametrize(
    "top_fn", [vld_like, group_like], ids=["replica", "group"]
)
@pytest.mark.parametrize("k_max", [16, 33, 64, 128, 512])
def test_three_way_allocator_parity(top_fn, k_max):
    top = top_fn()
    k_min = int(top.min_feasible_allocation().sum())
    if k_max < k_min:
        pytest.skip("budget below stability floor")
    naive = assign_processors_naive(top, k_max)
    heap = assign_processors(top, k_max)
    table = assign_processors_table(top, k_max)
    np.testing.assert_array_equal(table.k, naive.k)  # bit-identical decisions
    np.testing.assert_array_equal(heap.k, naive.k)
    assert table.expected_sojourn == naive.expected_sojourn
    assert table.total == naive.total


@pytest.mark.parametrize("k_max", range(11, 41))
def test_table_parity_dense_budget_sweep(k_max):
    """Every budget in a dense range — catches tie-break drift that a
    sparse sweep can miss."""
    top = vld_like()
    np.testing.assert_array_equal(
        assign_processors_table(top, k_max).k, assign_processors_naive(top, k_max).k
    )


def test_table_parity_scaled_load_k512():
    """Load scaled with the budget (the bench_overhead regime)."""
    top = vld_like(lam0=13.0 * 512 / 22.0)
    naive = assign_processors_naive(top, 512)
    table = assign_processors_table(top, 512)
    heap = assign_processors(top, 512)
    np.testing.assert_array_equal(table.k, naive.k)
    np.testing.assert_array_equal(heap.k, naive.k)


def test_greedy_increments_tie_breaking_matches_argmax():
    """Two identical operators: argmax gives the lower index the first of
    every tied pair; counts may differ by at most one in its favour."""
    top = Topology.chain([("a", 4.0), ("b", 4.0)], lam0=0.0)
    # zero traffic -> all gains 0 -> nothing taken
    _, G = gain_table(top, 8)
    take = greedy_increments(G, np.array([1, 1]), 4)
    assert take.tolist() == [0, 0]

    top2 = Topology(
        [OperatorSpec("a", 4.0), OperatorSpec("b", 4.0)],
        np.array([3.0, 3.0]),
        np.zeros((2, 2)),
    )
    for k_max in range(2, 12):
        np.testing.assert_array_equal(
            assign_processors_table(top2, k_max).k,
            assign_processors_naive(top2, k_max).k,
        )


def test_greedy_increments_rejects_narrow_table():
    top = vld_like()
    _, G = gain_table(top, 10)
    with pytest.raises(ValueError):
        greedy_increments(G, np.array([7, 3, 1]), 8)  # needs column 14


def test_min_processors_table_parity():
    top = vld_like()
    for t_max in (2.0, 1.2, 0.9, 0.75):
        a = min_processors_table(top, t_max)
        b = min_processors(top, t_max)
        assert a.expected_sojourn <= t_max
        assert a.total == b.total
        np.testing.assert_array_equal(a.k, b.k)


def test_min_processors_table_unreachable_raises():
    top = vld_like()
    with pytest.raises(InsufficientResourcesError):
        min_processors_table(top, 0.5)  # below the 0.72 service floor
    with pytest.raises(InsufficientResourcesError):
        min_processors_table(top, 0.73, k_cap=12)  # cap below requirement


def test_min_processors_table_group_scaling():
    top = group_like()
    res = min_processors_table(top, 0.9)
    assert res.expected_sojourn <= 0.9
    ref = min_processors(top, 0.9)
    assert res.total == ref.total


def test_table_evaluations_counted():
    top = vld_like()
    res = assign_processors_table(top, 30)
    assert res.evaluations > 0  # table entries materialised
