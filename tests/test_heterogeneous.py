"""Heterogeneous-processor allocation (paper §III-A extension)."""

import math

import numpy as np
import pytest

from repro.core import Topology, assign_processors
from repro.core.allocator import InsufficientResourcesError
from repro.core.heterogeneous import SpeedPool, assign_heterogeneous


def vld():
    return Topology.chain([("extract", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=13.0)


def test_homogeneous_pool_matches_algorithm1():
    """Speed-1 pool must reproduce the homogeneous optimum exactly."""
    top = vld()
    pool = SpeedPool.of({1.0: 22})
    het = assign_heterogeneous(top, pool)
    hom = assign_processors(top, 22)
    np.testing.assert_array_equal(het.k, hom.k)
    assert het.expected_sojourn == pytest.approx(hom.expected_sojourn, rel=1e-9)


def test_fast_processors_go_to_bottleneck():
    top = vld()
    pool = SpeedPool.of({2.0: 4, 1.0: 18})
    het = assign_heterogeneous(top, pool)
    assert math.isfinite(het.expected_sojourn)
    # the 2x processors should land on the heavy operators (extract/match),
    # not the idle aggregator
    assert all(s == 1.0 for s in het.speeds[2])
    fast_used = sum(s == 2.0 for ops in het.speeds for s in ops)
    assert fast_used == 4
    assert sum(s == 2.0 for s in het.speeds[0]) >= 2  # extract is the bottleneck


def test_faster_pool_beats_slower_pool():
    top = vld()
    slow = assign_heterogeneous(top, SpeedPool.of({1.0: 22}))
    fast = assign_heterogeneous(top, SpeedPool.of({2.0: 8, 1.0: 14}))
    assert fast.expected_sojourn < slow.expected_sojourn


def test_insufficient_heterogeneous_pool_raises():
    top = vld()
    with pytest.raises(InsufficientResourcesError):
        assign_heterogeneous(top, SpeedPool.of({0.5: 10}))  # capacity 2.5*... < needs


def test_mixed_pool_stabilises_all_operators():
    top = vld()
    het = assign_heterogeneous(top, SpeedPool.of({1.5: 6, 1.0: 10, 0.5: 10}))
    mu_eff = het.effective_mu([op.mu for op in top.operators])
    lam = top.arrival_rates
    for i in range(top.n):
        assert het.k[i] * mu_eff[i] > lam[i]  # stable everywhere


# --------------------------------------------------------------------------- #
# Controller wiring (ISSUE 5): machine-class speed factors scale per-op mu
# in the batched decide path, consistent with the scalar heterogeneous math
# --------------------------------------------------------------------------- #
def test_uniform_speed_pool_matches_speed_factor_scheduler():
    """A uniform-speed pool is the exact case of the mean-speed M/M/k
    approximation: assign_heterogeneous at speed s == Algorithm 1 on
    mu*s == DRSScheduler(speed_factors=s) Program 4."""
    import numpy as np

    from repro.core import OperatorSpec
    from repro.core.measurer import MeasurementSnapshot
    from repro.core.scheduler import DRSScheduler, SchedulerConfig

    top = vld()
    s, k_total = 0.5, 30
    het = assign_heterogeneous(top, SpeedPool.of({s: k_total}))
    scaled = Topology(
        [OperatorSpec(op.name, op.mu * s) for op in top.operators],
        top.lam0, top.routing,
    )
    hom = assign_processors(scaled, k_total)
    np.testing.assert_array_equal(het.k, hom.k)
    assert het.expected_sojourn == pytest.approx(hom.expected_sojourn, rel=1e-9)

    routing = top.routing
    names = [op.name for op in top.operators]
    sched = DRSScheduler(
        names, routing, het.k.copy(),
        SchedulerConfig(k_max=k_total),
        speed_factors=[s] * top.n,
    )
    lam = top.arrival_rates
    snap = MeasurementSnapshot.from_rates(
        lam, [op.mu for op in top.operators], top.lam0_total, 1.0, 60.0
    )
    d = sched.tick_from(snap, 60.0)
    assert d.action in ("none", "rebalance")
    np.testing.assert_array_equal(d.k_target, het.k)
    assert d.model_sojourn_target == pytest.approx(het.expected_sojourn, rel=1e-9)


def test_scenario_speed_factors_match_prescaled_mus():
    """Zoo wiring: a Scenario with speed_factors decides bit-identically
    to the same scenario with the factors baked into the declared mus
    (sim capacity, synthetic measurement, and model all agree)."""
    import numpy as np

    from repro.api.graph import AppGraph, Edge, OpDef
    from repro.api.session import ScenarioRunner
    from repro.streaming.scenarios import ArrivalTrace, Scenario

    def graph(scale):
        return AppGraph(
            [OpDef("a", mu=2.0 * scale[0]), OpDef("b", mu=5.0 * scale[1]),
             OpDef("c", mu=50.0 * scale[2])],
            [Edge("a", "b"), Edge("b", "c")],
            {"a": 10.0},
        )

    factors = (0.5, 1.5, 1.0)
    base = dict(
        traces={"a": ArrivalTrace(kind="flash", rate=8.0, peak=16.0,
                                  t_on=10.0, t_off=20.0)},
        seed=3, horizon=30.0, warmup=5.0, dt=0.05, k_max=32, t_max=None,
    )
    hetero = Scenario(
        name="hetero", graph=graph((1.0, 1.0, 1.0)),
        speed_factors={"a": factors[0], "b": factors[1], "c": factors[2]},
        **base,
    )
    baked = Scenario(name="baked", graph=graph(factors), **base)

    r_het = ScenarioRunner([hetero], tick_interval=5.0, backend="numpy")
    r_bak = ScenarioRunner([baked], tick_interval=5.0, backend="numpy")
    rep_het = r_het.run()[0]
    rep_bak = r_bak.run()[0]
    assert list(rep_het.actions) == list(rep_bak.actions)
    assert rep_het.k_final == rep_bak.k_final
    np.testing.assert_array_equal(r_het.k, r_bak.k)


def test_negotiated_scenario_lease_carries_machine_speed():
    """The scenario zoo's optional leases tag machines with the class
    speed (Machine.speed) when the scenario is heterogeneous."""
    from repro.api.session import ScenarioRunner
    from repro.streaming.scenarios import vld_scenario

    s = vld_scenario(speed_factors={"extract": 0.5, "match": 0.5, "aggregate": 0.5})
    runner = ScenarioRunner([s], tick_interval=10.0, backend="numpy")
    neg = runner.negotiators[0]
    assert neg is not None
    machines = neg.pool.leased + neg.pool.available
    assert machines and all(m.speed == 0.5 for m in machines)
