"""Heterogeneous-processor allocation (paper §III-A extension)."""

import math

import numpy as np
import pytest

from repro.core import Topology, assign_processors
from repro.core.allocator import InsufficientResourcesError
from repro.core.heterogeneous import SpeedPool, assign_heterogeneous


def vld():
    return Topology.chain([("extract", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=13.0)


def test_homogeneous_pool_matches_algorithm1():
    """Speed-1 pool must reproduce the homogeneous optimum exactly."""
    top = vld()
    pool = SpeedPool.of({1.0: 22})
    het = assign_heterogeneous(top, pool)
    hom = assign_processors(top, 22)
    np.testing.assert_array_equal(het.k, hom.k)
    assert het.expected_sojourn == pytest.approx(hom.expected_sojourn, rel=1e-9)


def test_fast_processors_go_to_bottleneck():
    top = vld()
    pool = SpeedPool.of({2.0: 4, 1.0: 18})
    het = assign_heterogeneous(top, pool)
    assert math.isfinite(het.expected_sojourn)
    # the 2x processors should land on the heavy operators (extract/match),
    # not the idle aggregator
    assert all(s == 1.0 for s in het.speeds[2])
    fast_used = sum(s == 2.0 for ops in het.speeds for s in ops)
    assert fast_used == 4
    assert sum(s == 2.0 for s in het.speeds[0]) >= 2  # extract is the bottleneck


def test_faster_pool_beats_slower_pool():
    top = vld()
    slow = assign_heterogeneous(top, SpeedPool.of({1.0: 22}))
    fast = assign_heterogeneous(top, SpeedPool.of({2.0: 8, 1.0: 14}))
    assert fast.expected_sojourn < slow.expected_sojourn


def test_insufficient_heterogeneous_pool_raises():
    top = vld()
    with pytest.raises(InsufficientResourcesError):
        assign_heterogeneous(top, SpeedPool.of({0.5: 10}))  # capacity 2.5*... < needs


def test_mixed_pool_stabilises_all_operators():
    top = vld()
    het = assign_heterogeneous(top, SpeedPool.of({1.5: 6, 1.0: 10, 0.5: 10}))
    mu_eff = het.effective_mu([op.mu for op in top.operators])
    lam = top.arrival_rates
    for i in range(top.n):
        assert het.k[i] * mu_eff[i] > lam[i]  # stable everywhere
