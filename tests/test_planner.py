"""FleetPlanner / FleetSession — cross-tenant scheduling (DESIGN.md §12)."""

import time

import numpy as np
import pytest

from repro.api import AppGraph, FleetSession, OpDef, SchedulerConfig
from repro.core import (
    FleetPlanner,
    InsufficientResourcesError,
    Machine,
    Negotiator,
    ResourcePool,
    Tenant,
    assign_processors_naive,
)
from repro.core.jackson import OperatorSpec, Topology


def chain_graph(i: int, lam0: float, mus=(2.0, 6.0, 30.0)) -> AppGraph:
    return AppGraph.chain(
        [(f"a{i}", mus[0]), (f"b{i}", mus[1]), (f"c{i}", mus[2])], lam0=lam0
    )


def ten_tenant_fleet(t_max=1.5):
    return [
        Tenant(name=f"t{i}", graph=chain_graph(i, 4.0 + 1.5 * i), t_max=t_max)
        for i in range(10)
    ]


# ------------------------------------------------------------------ #
# FleetPlanner
# ------------------------------------------------------------------ #
def test_fleet_plan_ten_tenants_tmax_honored():
    """>= 8 tenant graphs against one shared pool; every per-tenant T_max
    constraint met and the pool bound respected."""
    planner = FleetPlanner(ten_tenant_fleet(), k_max=220)
    plan = planner.plan()
    assert len(plan.per_tenant) == 10
    assert plan.total <= 220
    assert not plan.overloaded and plan.unmet == ()
    for name, res in plan.per_tenant.items():
        assert res.expected_sojourn <= 1.5, name


def test_fleet_plan_spends_whole_pool_when_beneficial():
    planner = FleetPlanner(ten_tenant_fleet(), k_max=220)
    assert planner.plan().total == 220  # marginal gains still positive


def test_fleet_throughput_objective_equals_blockdiag_program4():
    """w_m = 1 makes the merged greedy literally Program (4) on the
    block-diagonal union of the tenant networks."""
    g1 = AppGraph.chain([("x1", 2.0), ("y1", 5.0)], lam0=6.0)
    g2 = AppGraph.chain([("x2", 3.0), ("y2", 8.0)], lam0=9.0)
    plan = FleetPlanner(
        [Tenant("p", graph=g1), Tenant("q", graph=g2)], 30, objective="throughput"
    ).plan()
    ops = [
        OperatorSpec("x1", 2.0), OperatorSpec("y1", 5.0),
        OperatorSpec("x2", 3.0), OperatorSpec("y2", 8.0),
    ]
    routing = np.zeros((4, 4))
    routing[0][1] = 1.0
    routing[2][3] = 1.0
    combo = Topology(ops, np.array([6.0, 0.0, 9.0, 0.0]), routing)
    ref = assign_processors_naive(combo, 30)
    np.testing.assert_array_equal(
        np.concatenate([plan.k["p"], plan.k["q"]]), ref.k
    )


def test_fleet_fair_objective_weights_small_tenants():
    """Fair weighting gives the low-traffic tenant a larger share than
    throughput weighting does."""
    g_small = AppGraph.chain([("s1", 2.0), ("s2", 6.0)], lam0=2.0)
    g_big = AppGraph.chain([("b1", 2.0), ("b2", 6.0)], lam0=20.0)
    tenants = [Tenant("small", graph=g_small), Tenant("big", graph=g_big)]
    fair = FleetPlanner(tenants, 40, objective="fair").plan()
    thr = FleetPlanner(tenants, 40, objective="throughput").plan()
    assert fair.k["small"].sum() >= thr.k["small"].sum()


def test_fleet_overloaded_when_floors_exceed_pool():
    """PR-2 overload semantics: floors > pool -> flagged, pool still fully
    distributed best-effort, violating tenants listed in unmet."""
    tenants = [
        Tenant(f"o{i}", graph=AppGraph.chain([(f"u{i}", 2.0)], lam0=10.0), t_max=0.51)
        for i in range(4)
    ]
    plan = FleetPlanner(tenants, 26).plan()
    assert plan.overloaded
    assert plan.needed_total > 26
    assert plan.total == 26  # best effort: whole pool handed out
    assert set(plan.unmet) == {"o0", "o1", "o2", "o3"}


def test_fleet_infeasible_minima_raise():
    with pytest.raises(InsufficientResourcesError):
        FleetPlanner(
            [Tenant("z", graph=AppGraph.chain([("w", 2.0)], lam0=50.0))], 10
        ).plan()


def test_fleet_unreachable_tmax_listed_not_fatal():
    """T_max below a tenant's service floor can't be bought with processors
    — the tenant is reported, the rest of the fleet still schedules."""
    tenants = [
        Tenant("ok", graph=chain_graph(0, 8.0), t_max=2.0),
        # floor = 1/2 + 1/6 + 1/30 = 0.7 > 0.1
        Tenant("impossible", graph=chain_graph(1, 8.0), t_max=0.1),
    ]
    plan = FleetPlanner(tenants, 60).plan()
    assert plan.unreachable == ("impossible",)
    assert "ok" not in plan.unmet
    assert plan.per_tenant["ok"].expected_sojourn <= 2.0


def test_fleet_measured_topology_override():
    """plan(topologies=...) replaces a tenant's declared priors (the
    control loop passes measured rebuilds through this)."""
    tenants = [
        Tenant("m", graph=chain_graph(0, 5.0), t_max=2.0),
        Tenant("other", graph=chain_graph(1, 5.0), t_max=2.0),
    ]
    planner = FleetPlanner(tenants, 40)
    base = planner.plan()
    doubled = chain_graph(0, 10.0).topology()
    heavier = planner.plan({"m": doubled})
    # the measured tenant's load doubled -> it wins pool share from the other
    assert heavier.k["m"].sum() > base.k["m"].sum()
    assert heavier.k["other"].sum() < base.k["other"].sum()


def test_tenant_validation():
    with pytest.raises(ValueError):
        Tenant("bad")  # neither graph nor topology
    with pytest.raises(ValueError):
        Tenant("bad", graph=chain_graph(0, 1.0), weight=0.0)
    with pytest.raises(ValueError):
        FleetPlanner(
            [Tenant("d", graph=chain_graph(0, 1.0))] * 2, 10
        )  # duplicate names


# ------------------------------------------------------------------ #
# plan_batched: the kernel/sharded top-R solve vs the scalar greedy
# ------------------------------------------------------------------ #
def _plans_equal(a, b):
    assert set(a.k) == set(b.k)
    for name in a.k:
        np.testing.assert_array_equal(a.k[name], b.k[name], err_msg=name)
    assert a.total == b.total and a.overloaded == b.overloaded
    assert a.unmet == b.unmet and a.unreachable == b.unreachable


@pytest.mark.parametrize("objective", ["fair", "throughput"])
def test_plan_batched_matches_scalar_greedy(objective):
    planner = FleetPlanner(ten_tenant_fleet(), k_max=220, objective=objective)
    _plans_equal(planner.plan(), planner.plan_batched())


def test_plan_batched_matches_when_overloaded():
    tenants = [
        Tenant(f"o{i}", graph=AppGraph.chain([(f"u{i}", 2.0)], lam0=10.0), t_max=0.51)
        for i in range(4)
    ]
    planner = FleetPlanner(tenants, 26)
    _plans_equal(planner.plan(), planner.plan_batched())


def test_plan_batched_matches_tight_pool():
    """Pool between the floors and the T_max-satisfying total: the greedy
    spends a small budget where gains are steepest — the batched top-R
    must pick the identical increments."""
    planner = FleetPlanner(ten_tenant_fleet(), k_max=205)
    _plans_equal(planner.plan(), planner.plan_batched())


def test_plan_batched_on_fleet_mesh_matches():
    """The cross-device fleet reduction (all-gather of per-shard gain
    tables, DESIGN.md §16) solves the same Program (4)."""
    import jax

    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices")
    from repro.distributed.sharding import fleet_mesh

    planner = FleetPlanner(ten_tenant_fleet(), k_max=220)
    _plans_equal(planner.plan(), planner.plan_batched(mesh=fleet_mesh(2)))
    # R=10 tenants... rows; 4-way mesh exercises row padding
    if len(jax.devices()) >= 4:
        _plans_equal(planner.plan(), planner.plan_batched(mesh=fleet_mesh(4)))


# ------------------------------------------------------------------ #
# FleetSession (model-only + negotiator-driven)
# ------------------------------------------------------------------ #
def chain_graph_2(i, lam0, mus=(2.0, 6.0)):
    return AppGraph.chain([(f"a{i}", mus[0]), (f"b{i}", mus[1])], lam0=lam0)


def make_sessions(n=8, t_max=1.2):
    return {
        f"t{i}": chain_graph_2(i, 4.0 + i).bind(
            "des", config=SchedulerConfig(t_max=t_max)
        )
        for i in range(n)
    }


def test_fleet_session_start_and_tick_model_only():
    fleet = FleetSession(make_sessions(), k_max=90)
    ks = fleet.start()
    assert set(ks) == {f"t{i}" for i in range(8)}
    assert sum(sum(v.values()) for v in ks.values()) <= 90
    d = fleet.tick(now=0.0)
    assert d.action in ("none", "rebalance")
    plan = fleet.plan()
    for name, res in plan.per_tenant.items():
        assert res.expected_sojourn <= 1.2, name


def test_fleet_session_negotiator_acquires_lease():
    pool = ResourcePool([Machine(f"m{i}", 4) for i in range(40)])
    neg = Negotiator(pool)
    fleet = FleetSession(make_sessions(), negotiator=neg)
    fleet.start()
    assert fleet.k_max > 0  # start() leased the floors
    d = fleet.tick(now=0.0)
    assert d.action in ("none", "rebalance")
    total = sum(sum(v.values()) for v in fleet.allocations().values())
    assert total <= fleet.k_max


def test_fleet_session_requires_budget():
    from repro.api import GraphValidationError

    with pytest.raises(GraphValidationError):
        FleetSession(make_sessions(2))


def test_fleet_session_engine_tenants_live():
    """Two live engine tenants on one pool: start under the planned split,
    drive traffic, tick the fleet, shut down cleanly."""

    def fast(_x):
        time.sleep(0.001)
        return []

    sessions = {}
    for i in range(2):
        g = AppGraph(
            [OpDef(f"w{i}", mu=400.0, fn=fast)], [], {f"w{i}": 30.0}
        )
        sessions[f"live{i}"] = g.bind(
            "engine", config=SchedulerConfig(t_max=1.0), queue_capacity=1000
        )
    fleet = FleetSession(sessions, k_max=8)
    try:
        ks = fleet.start()
        assert all(sum(v.values()) >= 1 for v in ks.values())
        t0 = time.perf_counter()
        sent = 0
        while time.perf_counter() - t0 < 0.8:
            for name in sessions:
                sessions[name].inject(sent)
            sent += 1
            time.sleep(0.01)
        d = fleet.tick()
        assert d.action in ("none", "rebalance", "overloaded")
        total = sum(sum(v.values()) for v in fleet.allocations().values())
        assert total <= fleet.k_max
    finally:
        fleet.stop()


def test_fleet_session_overload_fast_path():
    """A tenant measuring rho >= 1 makes the fleet tick 'overloaded' and
    leases immediately (no improvement gate, PR-2 semantics)."""
    g = AppGraph.chain([("hot", 2.0)], lam0=4.0)
    session = g.bind("des", config=SchedulerConfig(t_max=2.0))
    pool = ResourcePool([Machine(f"m{i}", 2) for i in range(20)])
    neg = Negotiator(pool)
    fleet = FleetSession({"hot": session}, negotiator=neg)
    fleet.start()
    k_before = fleet.k_max
    # Hand-feed an overloaded snapshot: offered 10/s >> capacity.
    sched = session.scheduler
    m = sched.measurer
    probe = m.new_probe("hot")
    m.pull(0.0)
    probe.on_enqueue(600)  # 10/s over 60s at the queue tail
    for _ in range(30):
        for _ in range(m.n_m - 1):
            probe.on_processed(0.0)
        probe.on_processed(0.5)  # mu = 2
    m.on_external_arrival(120)  # admitted only
    m.on_tuple_complete(3.0, 120)
    d = fleet.tick(now=60.0)
    assert d.action == "overloaded"
    assert "hot" in d.overloaded_tenants
    assert fleet.k_max >= k_before
    # the offered-load model needs ceil(10/2)+ = 6 processors for stability
    assert sum(fleet.allocations()["hot"].values()) >= 6


def test_fleet_idle_tenant_measured_zero_traffic_does_not_crash():
    """A quiet measurement window (lam0 == 0) must not kill the fleet
    plan with a division error under the fair objective."""
    tenants = [
        Tenant("busy", graph=chain_graph(0, 8.0)),
        Tenant("idle", graph=chain_graph(1, 5.0)),
    ]
    planner = FleetPlanner(tenants, 40)
    quiet = Topology(
        [OperatorSpec("a1", 2.0), OperatorSpec("b1", 6.0), OperatorSpec("c1", 30.0)],
        np.zeros(3),
        chain_graph(1, 5.0).routing_matrix(),
    )
    plan = planner.plan({"idle": quiet})
    assert np.isfinite(plan.objective)
    assert plan.k["busy"].sum() + plan.k["idle"].sum() <= 40


def test_fleet_session_no_scale_in_without_tmax():
    """Tenants without latency targets must never have their lease
    released down to the stability floor (the 'need' isn't a target)."""
    pool = ResourcePool([Machine(f"m{i}", 4) for i in range(30)])
    neg = Negotiator(pool)
    neg.ensure(100)
    sessions = {
        f"t{i}": chain_graph_2(i, 4.0 + i).bind("des", config=SchedulerConfig())
        for i in range(3)
    }
    fleet = FleetSession(sessions, negotiator=neg)
    fleet.start()
    k_leased = fleet.k_max
    d = fleet.tick(now=0.0)
    assert d.action != "scale_in"
    assert fleet.k_max == k_leased  # lease untouched
    total = sum(sum(v.values()) for v in fleet.allocations().values())
    assert total <= fleet.k_max


def test_fleet_session_scale_in_applies_shrunk_allocation():
    """All tenants declare T_max and the lease is fat: the tick must
    release AND re-apply in one step, leaving total <= new k_max."""
    pool = ResourcePool([Machine(f"m{i}", 4) for i in range(40)])
    neg = Negotiator(pool)
    neg.ensure(140)
    sessions = {
        f"t{i}": chain_graph_2(i, 4.0 + i).bind(
            "des", config=SchedulerConfig(t_max=1.2)
        )
        for i in range(3)
    }
    fleet = FleetSession(sessions, negotiator=neg)
    fleet.start()
    assert fleet.k_max == 140
    d = fleet.tick(now=0.0)
    assert d.action == "scale_in"
    assert fleet.k_max < 140
    total = sum(sum(v.values()) for v in fleet.allocations().values())
    assert total <= fleet.k_max
    for name in sessions:
        assert d.plan.per_tenant[name].expected_sojourn <= 1.2
