"""Subprocess body for test_moe_ep: GSPMD vs shard_map-EP equivalence.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the test
sets it).  Uses a no-drop capacity regime so both dispatch paths are
exact; checks forward outputs and gradients.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig, axis_rules
from repro.models.ffn import moe_layer, moe_layer_ep


def main() -> None:
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = ModelConfig(
        arch="ep-test", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=64, n_experts=8, top_k=2,
        capacity_factor=8.0,  # no-drop regime for exact equivalence
        n_shared_experts=1, moe_d_ff=64, dtype=jnp.float32,
    )
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.expert_ff
    params = {
        "router": jax.random.normal(ks[0], (d, e)) * 0.3,
        "wi_gate": jax.random.normal(ks[1], (e, d, f)) * 0.1,
        "wi_up": jax.random.normal(ks[2], (e, d, f)) * 0.1,
        "wo": jax.random.normal(ks[3], (e, f, d)) * 0.1,
        "shared": {
            "wi_gate": jax.random.normal(ks[4], (d, f)) * 0.1,
            "wi_up": jax.random.normal(ks[5], (d, f)) * 0.1,
            "wo": jax.random.normal(ks[6], (f, d)) * 0.1,
        },
    }
    x = jax.random.normal(ks[7], (8, 16, d))

    rules = {"batch": "data", "d_ff": "model", "experts": "data"}

    def f_gspmd(p, x):
        with axis_rules(rules, mesh):
            out, aux = moe_layer(p, x, cfg)
        return out, aux

    def f_ep(p, x):
        with axis_rules(rules, mesh):
            out, aux = moe_layer_ep(p, x, cfg)
        return out, aux

    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    with mesh:
        out_g, aux_g = jax.jit(f_gspmd)(params, xs)
        out_e, aux_e = jax.jit(f_ep)(params, xs)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_e), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_g), float(aux_e), rtol=1e-4)
    print("forward OK")

    def loss_g(p, x):
        out, aux = f_gspmd(p, x)
        return (out.astype(jnp.float32) ** 2).mean() + 0.01 * aux

    def loss_e(p, x):
        out, aux = f_ep(p, x)
        return (out.astype(jnp.float32) ** 2).mean() + 0.01 * aux

    with mesh:
        g_g = jax.jit(jax.grad(loss_g))(params, xs)
        g_e = jax.jit(jax.grad(loss_e))(params, xs)
    for (ka, va), (kb, vb) in zip(
        sorted(jax.tree_util.tree_flatten_with_path(g_g)[0], key=lambda t: str(t[0])),
        sorted(jax.tree_util.tree_flatten_with_path(g_e)[0], key=lambda t: str(t[0])),
    ):
        np.testing.assert_allclose(
            np.asarray(va), np.asarray(vb), rtol=5e-4, atol=5e-4,
            err_msg=f"grad mismatch at {ka}",
        )
    print("grads OK")


if __name__ == "__main__":
    main()
