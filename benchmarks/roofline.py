"""Roofline report: aggregate dry-run records into the §Roofline table.

Reads benchmarks/results/dryrun/*.json and emits a markdown table plus a
per-cell summary of the three terms, the dominant bottleneck, MODEL_FLOPS
vs compiled FLOPs, and what would move the dominant term.

  PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16] [--tag x]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"

ARCH_ORDER = [
    "rwkv6-1.6b", "command-r-35b", "llama3.2-1b", "yi-34b", "phi3-medium-14b",
    "qwen2-vl-2b", "mixtral-8x22b", "kimi-k2-1t-a32b", "zamba2-7b", "whisper-medium",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _advice(r: dict) -> str:
    d = r["dominant"]
    if d == "compute":
        return "raise MXU occupancy: larger per-chip tiles / fewer pods"
    if d == "memory":
        return "cut HBM traffic: chunked attention, fused FFN, better remat"
    return "cut collective bytes: shard_map EP, overlap, gradient compression"


def load(mesh: str, tag: str = "") -> list[dict]:
    recs = []
    suffix = f"--{mesh}{('-' + tag) if tag else ''}.json"
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            p = RESULTS / f"{arch}--{shape}{suffix}"
            if p.exists():
                recs.append(json.loads(p.read_text()))
    return recs


def table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "bound s | MODEL_FLOPS | useful | HBM GB/dev | next move |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        if rec.get("status") == "skipped":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | skipped | — | — | — | — | "
                f"{rec['reason'][:60]}… |"
            )
            continue
        if rec.get("status") != "ok":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | ERROR | — | — | — | — | "
                f"{rec.get('error', '')[:60]} |"
            )
            continue
        r = rec["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        mem = rec.get("memory_analysis", {})
        hbm = (
            mem.get("argument_size_in_bytes", 0)
            + mem.get("temp_size_in_bytes", 0)
            + mem.get("output_size_in_bytes", 0)
            - mem.get("alias_size_in_bytes", 0)
        ) / 2**30
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.4g} | "
            f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | {r['dominant']} | "
            f"{bound:.4g} | {r['model_flops']:.3g} | {r['useful_ratio']:.2f} | "
            f"{hbm:.1f} | {_advice(r)} |"
        )
    return "\n".join(lines)


def kernel_intensity_rows() -> list[tuple[str, float, float, str]]:
    """Analytic (name, flops, hbm_bytes, note) rows for hand-written
    kernels whose intensity comes from the BlockSpec tiling, not a
    dry-run record.  Today: the fused batch-decide pass (DESIGN.md §12).
    """
    b, n, k, j_cap = 16, 8, 512, 48
    # Per (lane, k) cell: Erlang-B step (3 flops: mul, fma, div), B->C
    # conversion (~6), t_rep + mask (~5), gain row (~3).  Selection adds
    # the 31-step threshold bisection (one masked count-reduce over the
    # j_cap window each) and two final count/tie passes.
    flops = b * n * (k * 17 + (31 + 2) * 2 * j_cap + 4 * 2)
    # HBM: read 5 f32 + 2 i32 per lane + 1 i32 budget per scenario,
    # write 4 f32 per lane.  T [B,N,K+1] and G [B,N,K] never leave VMEM
    # — the two-pass path round-trips both (the fusion's whole point).
    hbm = 4 * (7 * b * n + b) + 4 * 4 * b * n
    saved = 2 * 4 * (b * n * (k + 1) + b * n * k)
    note = (
        f"B={b} N={n} K={k} j_cap={j_cap}; keeps T+G VMEM-resident "
        f"(saves {saved / 2**20:.2f} MiB/decide vs two-pass)"
    )
    return [("decide_fused", float(flops), float(hbm), note)]


def kernel_intensity_table() -> str:
    lines = [
        "| kernel | flops | HBM bytes | flop/byte | note |",
        "|---|---|---|---|---|",
    ]
    for name, flops, hbm, note in kernel_intensity_rows():
        lines.append(
            f"| {name} | {flops:.3g} | {hbm:.3g} | {flops / hbm:.0f} | {note} |"
        )
    return "\n".join(lines)


def roofline_fraction(rec: dict) -> float:
    """compute_s / bound_s: how close the cell is to its compute roofline."""
    r = rec["roofline"]
    bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
    return r["compute_s"] / bound if bound > 0 else 0.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    recs = load(args.mesh, args.tag)
    if args.csv:
        print("arch,shape,compute_s,memory_s,collective_s,dominant,roofline_fraction")
        for rec in recs:
            if rec.get("status") != "ok":
                continue
            r = rec["roofline"]
            print(
                f"{rec['arch']},{rec['shape']},{r['compute_s']:.6g},{r['memory_s']:.6g},"
                f"{r['collective_s']:.6g},{r['dominant']},{roofline_fraction(rec):.4f}"
            )
        return
    print(table(recs))
    print("\n### Kernel arithmetic intensity (analytic)\n")
    print(kernel_intensity_table())
    ok = [r for r in recs if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=roofline_fraction)
        coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
        print(f"\nworst roofline fraction: {worst['arch']} x {worst['shape']} "
              f"({roofline_fraction(worst):.4f})")
        print(f"most collective-bound:   {coll['arch']} x {coll['shape']} "
              f"({coll['roofline']['collective_s']:.3g}s)")


if __name__ == "__main__":
    main()
