"""Fig. 8 reproduction: degree of underestimation vs per-tuple CPU time.

The paper runs a synthetic 3-bolt chain and shows the measured/estimated
sojourn ratio decreasing as compute per tuple grows (network cost is
out-of-model).  We reproduce with the DES's per-hop network delay as the
out-of-model cost, sweeping the bolts' total CPU time — and we add the
TPU-side counterpart (DESIGN.md §10): when the model *does* include a
deterministic per-hop cost prior, the underestimation shrinks.
"""

from __future__ import annotations

from repro.api import AppGraph


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    hop = 0.004  # 4 ms per-hop network delay (out of model)
    sweep = (2.0, 32.0, 512.0) if smoke else (0.5, 2.0, 8.0, 32.0, 128.0, 512.0)
    for total_cpu_ms in sweep:
        mu = 3.0 / (total_cpu_ms / 1e3)  # 3 bolts, equal split
        graph = AppGraph.chain(
            [("b1", mu), ("b2", mu), ("b3", mu)], lam0=min(0.5 * mu, 200.0)
        )
        top = graph.topology()
        k = list(top.min_feasible_allocation() + 1)
        horizon = max(150.0, 15000.0 / mu) if smoke else max(400.0, 40000.0 / mu)
        sim = graph.bind(
            "des", seed=11, horizon=horizon, warmup=20.0,
            network_delay=hop,
        ).simulate(k)
        est = top.expected_sojourn(k)
        ratio = sim.mean_sojourn / est
        rows.append((
            f"underestimation_cpu{total_cpu_ms}ms", ratio,
            f"measured/estimated (est {est*1e3:.2f} ms)",
        ))
        # TPU counterpart: deterministic hop prior folded into the model
        est_with_hop = est + 3 * hop
        rows.append((
            f"underestimation_with_hop_prior_cpu{total_cpu_ms}ms",
            sim.mean_sojourn / est_with_hop,
            "ratio with deterministic per-hop prior (DESIGN §10)",
        ))
    return rows


def main() -> None:
    for name, val, note in run():
        print(f"{name},{val:.4f},{note}")


if __name__ == "__main__":
    main()
