"""Scenario-matrix benchmark: batch simulator throughput vs sequential DES
(DESIGN.md §13/§17).

The claim: the vectorized discrete-time batch simulator
(`streaming/batchsim.py`) turns the (topology x arrival-pattern x
overload-policy x allocator) space from a handful of hand-picked DES
points into hundreds of seeded scenarios per CI run.  Rows:

* ``batch_np_seconds_B{B}`` / ``batch_jax_seconds_B{B}`` — wall-clock for
  the whole B-scenario sweep on each backend (jax timed post-warmup: the
  jit compile is a once-per-process cost the sweep amortises);
* ``des_seconds_per_scenario`` — mean sequential event-DES cost on a
  sample of the same scenarios;
* ``speedup_batch_vs_des_B64`` — the acceptance gate: the B=64 sweep must
  run >= 20x faster through the batch simulator than through B sequential
  DES runs (best backend counted);
* ``conformance_*`` — the §17 fidelity gate, ASSERTED (not report-only):
  mean |batch - DES| / DES visit-sum sojourn over a dedicated
  longer-horizon stable matrix, with the DES side averaged over several
  seeds (single-seed flash/mmpp runs carry up to ~37% CV, which would
  make any sub-0.2 gate meaningless).  Per-family breakdown rows persist
  to ``BENCH_scenarios.json``.  Gates: < 0.2 for the stochastic matrix,
  < 0.05 for its deterministic (fluid-exact) variant;
* ``controlled_matrix_*`` — the measure -> model -> rebalance loop swept
  over the matrix by ``ScenarioRunner`` (the CI smoke runs this at B=32).
"""

from __future__ import annotations

import time
from dataclasses import replace

import numpy as np

from repro.api.session import ScenarioRunner
from repro.streaming.batchsim import BatchQueueSim
from repro.streaming.scenarios import pack_allocations, pack_scenarios, scenario_matrix

#: §17 fidelity gates — asserted below, mirrored by the tier-1
#: ``test_conformance_policy_family_matrix`` test.
CONFORMANCE_GATE_STABLE = 0.2
CONFORMANCE_GATE_DETERMINISTIC = 0.05


def _conformance(rows: list[tuple[str, float, str]], smoke: bool) -> None:
    """Dedicated longer-horizon conformance block.

    Separate from the throughput sweep on purpose: the timing matrix
    runs short horizons (30-60s) where neither simulator has converged,
    while the fidelity claim is about the converged visit-sum sojourn.
    The config is identical in smoke and full (h=240, 12 scenarios, DES
    averaged over 6 seeds, ~20s): fidelity is a correctness gate, not a
    timing row, and single-seed diurnal runs carry up to ~52% CV — the
    seed count is what makes the 0.2 gate meaningful, so smoke must not
    weaken it.
    """
    del smoke
    horizon = 240.0
    n_scen = 12
    n_seeds = 6
    scens = scenario_matrix(n_scen, seed=0, horizon=horizon, warmup=20.0, dt=0.05)
    det = [
        replace(s, name=s.name + "-det",
                arrival_kind="deterministic", service_kind="deterministic")
        for s in scens
    ]
    fam_errs: dict[str, list[float]] = {}
    variant_means: dict[str, float] = {}
    for variant, batch in (("stable", scens), ("deterministic", det)):
        arrays = pack_scenarios(batch)
        k = pack_allocations(batch, [s.plan_k0() for s in batch])
        res = BatchQueueSim(arrays, backend="numpy").run(k)
        soj = res.sojourn(k, arrays.mu, arrays.group, arrays.alpha,
                          ca2=arrays.ca2, cs2=arrays.cs2)
        sat = res.saturated(k, arrays.mu, arrays.group, arrays.alpha)
        errs = []
        for i, s in enumerate(batch):
            if sat[i].any():
                continue  # the §13 divergence bound applies to stable scenarios
            kd = dict(zip(s.graph.names, map(int, k[i, : s.graph.n])))
            seeds = (s.seed,) if variant == "deterministic" else tuple(
                s.seed + 1 + j for j in range(n_seeds)
            )
            des_vals = [s.simulator(kd, seed=sd).run().mean_visit_sum for sd in seeds]
            des = float(np.mean(des_vals))
            if not (np.isfinite(des) and des > 0):
                continue
            err = abs(float(soj[i]) - des) / des
            errs.append(err)
            if variant == "stable":
                fam_errs.setdefault(s.name.rsplit("-", 1)[-1], []).append(err)
        variant_means[variant] = float(np.mean(errs))
        rows.append((
            f"conformance_mean_rel_err_{variant}" if variant != "stable"
            else "conformance_mean_rel_err",
            variant_means[variant],
            f"visit-sum sojourn, {len(errs)} stable scenarios, h={horizon:g}, "
            f"DES x{len(seeds)} seeds (gate < "
            f"{CONFORMANCE_GATE_DETERMINISTIC if variant == 'deterministic' else CONFORMANCE_GATE_STABLE})",
        ))
    for fam in ("constant", "diurnal", "flash", "mmpp"):
        if fam in fam_errs:
            rows.append((
                f"conformance_rel_err_{fam}",
                float(np.mean(fam_errs[fam])),
                f"per-family breakdown, {len(fam_errs[fam])} scenarios",
            ))
    # The gate: asserted, so a fidelity regression fails the bench run
    # (and the CI bench-smoke lane) instead of rotting in a report row.
    assert variant_means["stable"] < CONFORMANCE_GATE_STABLE, (
        f"conformance_mean_rel_err={variant_means['stable']:.4f} "
        f">= {CONFORMANCE_GATE_STABLE} (stable matrix)"
    )
    assert variant_means["deterministic"] < CONFORMANCE_GATE_DETERMINISTIC, (
        f"conformance_mean_rel_err_deterministic={variant_means['deterministic']:.4f} "
        f">= {CONFORMANCE_GATE_DETERMINISTIC}"
    )


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    b = 32 if smoke else 64
    horizon = 30.0 if smoke else 60.0
    des_sample = 4 if smoke else 12
    scens = scenario_matrix(b, seed=0, horizon=horizon, warmup=5.0, dt=0.05)
    arrays = pack_scenarios(scens)
    k = pack_allocations(scens, [s.plan_k0() for s in scens])
    rows.append(("matrix_scenarios", float(b), f"scenarios, {arrays.steps} steps, N={arrays.n}"))

    t0 = time.perf_counter()
    BatchQueueSim(arrays, backend="numpy").run(k)
    t_np = time.perf_counter() - t0
    rows.append((f"batch_np_seconds_B{b}", t_np, "s whole-sweep (float64 twin)"))

    BatchQueueSim(arrays, backend="jax").run(k)  # compile warmup
    t0 = time.perf_counter()
    BatchQueueSim(arrays, backend="jax").run(k)
    t_jax = time.perf_counter() - t0
    rows.append((f"batch_jax_seconds_B{b}", t_jax, "s whole-sweep (jit, post-warmup)"))

    # Sequential event DES on a sample of the same scenarios (timing only;
    # fidelity moved to the dedicated asserted block below).
    t_des = 0.0
    for i in range(des_sample):
        s = scens[i]
        sim = s.simulator(dict(zip(s.graph.names, map(int, k[i, : s.graph.n]))))
        t0 = time.perf_counter()
        sim.run()
        t_des += time.perf_counter() - t0
    des_per = t_des / des_sample
    rows.append(("des_seconds_per_scenario", des_per, f"s mean over {des_sample} runs"))
    t_best = min(t_np, t_jax)
    rows.append((
        f"speedup_batch_vs_des_B{b}",
        des_per * b / t_best,
        "x vs sequential DES (acceptance: >= 20x at B=64)",
    ))

    _conformance(rows, smoke)

    # Full control loop over the matrix (the CI 32-scenario smoke).
    t0 = time.perf_counter()
    reports = ScenarioRunner(
        scenario_matrix(b, seed=1, horizon=horizon, warmup=5.0, dt=0.05),
        tick_interval=5.0,
    ).run()
    t_ctl = time.perf_counter() - t0
    actions = [a for r in reports for a in r.actions]
    rows.append((f"controlled_matrix_seconds_B{b}", t_ctl, "s measure->model->rebalance sweep"))
    rows.append((
        "controlled_matrix_active_fraction",
        sum(a != "none" for a in actions) / max(len(actions), 1),
        "fraction of ticks with a non-none decision",
    ))
    rows.append((
        "controlled_matrix_drop_rate",
        float(np.mean([r.drop_rate for r in reports])),
        "mean shed fraction under control",
    ))
    return rows
