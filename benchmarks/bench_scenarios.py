"""Scenario-matrix benchmark: batch simulator throughput vs sequential DES
(DESIGN.md §13).

The claim: the vectorized discrete-time batch simulator
(`streaming/batchsim.py`) turns the (topology x arrival-pattern x
overload-policy x allocator) space from a handful of hand-picked DES
points into hundreds of seeded scenarios per CI run.  Rows:

* ``batch_np_seconds_B{B}`` / ``batch_jax_seconds_B{B}`` — wall-clock for
  the whole B-scenario sweep on each backend (jax timed post-warmup: the
  jit compile is a once-per-process cost the sweep amortises);
* ``des_seconds_per_scenario`` — mean sequential event-DES cost on a
  sample of the same scenarios;
* ``speedup_batch_vs_des_B64`` — the acceptance gate: the B=64 sweep must
  run >= 20x faster through the batch simulator than through B sequential
  DES runs (best backend counted);
* ``conformance_mean_rel_err`` — mean |batch - DES| / DES visit-sum
  sojourn over the sampled stable scenarios (the §13 divergence bound in
  action);
* ``controlled_matrix_*`` — the measure -> model -> rebalance loop swept
  over the matrix by ``ScenarioRunner`` (the CI smoke runs this at B=32).
"""

from __future__ import annotations

import time

import numpy as np

from repro.api.session import ScenarioRunner
from repro.streaming.batchsim import BatchQueueSim
from repro.streaming.scenarios import pack_allocations, pack_scenarios, scenario_matrix


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    b = 32 if smoke else 64
    horizon = 30.0 if smoke else 60.0
    des_sample = 4 if smoke else 12
    scens = scenario_matrix(b, seed=0, horizon=horizon, warmup=5.0, dt=0.05)
    arrays = pack_scenarios(scens)
    k = pack_allocations(scens, [s.plan_k0() for s in scens])
    rows.append(("matrix_scenarios", float(b), f"scenarios, {arrays.steps} steps, N={arrays.n}"))

    t0 = time.perf_counter()
    res_np = BatchQueueSim(arrays, backend="numpy").run(k)
    t_np = time.perf_counter() - t0
    rows.append((f"batch_np_seconds_B{b}", t_np, "s whole-sweep (float64 twin)"))

    BatchQueueSim(arrays, backend="jax").run(k)  # compile warmup
    t0 = time.perf_counter()
    BatchQueueSim(arrays, backend="jax").run(k)
    t_jax = time.perf_counter() - t0
    rows.append((f"batch_jax_seconds_B{b}", t_jax, "s whole-sweep (jit, post-warmup)"))

    # Sequential event DES on a sample of the same scenarios.
    t_des = 0.0
    rel_errs = []
    for i in range(des_sample):
        s = scens[i]
        sim = s.simulator(dict(zip(s.graph.names, map(int, k[i, : s.graph.n]))))
        t0 = time.perf_counter()
        des = sim.run()
        t_des += time.perf_counter() - t0
        batch_soj = float(
            res_np.sojourn(k, arrays.mu, arrays.group, arrays.alpha)[i]
        )
        if np.isfinite(des.mean_visit_sum) and des.mean_visit_sum > 0:
            sat = res_np.saturated(k, arrays.mu, arrays.group, arrays.alpha)[i]
            if not sat.any():  # §13 bound applies to stable scenarios
                rel_errs.append(abs(batch_soj - des.mean_visit_sum) / des.mean_visit_sum)
    des_per = t_des / des_sample
    rows.append(("des_seconds_per_scenario", des_per, f"s mean over {des_sample} runs"))
    t_best = min(t_np, t_jax)
    rows.append((
        f"speedup_batch_vs_des_B{b}",
        des_per * b / t_best,
        "x vs sequential DES (acceptance: >= 20x at B=64)",
    ))
    if rel_errs:
        rows.append((
            "conformance_mean_rel_err",
            float(np.mean(rel_errs)),
            f"visit-sum sojourn, {len(rel_errs)} stable scenarios (target < 0.2)",
        ))

    # Full control loop over the matrix (the CI 32-scenario smoke).
    t0 = time.perf_counter()
    reports = ScenarioRunner(
        scenario_matrix(b, seed=1, horizon=horizon, warmup=5.0, dt=0.05),
        tick_interval=5.0,
    ).run()
    t_ctl = time.perf_counter() - t0
    actions = [a for r in reports for a in r.actions]
    rows.append((f"controlled_matrix_seconds_B{b}", t_ctl, "s measure->model->rebalance sweep"))
    rows.append((
        "controlled_matrix_active_fraction",
        sum(a != "none" for a in actions) / max(len(actions), 1),
        "fraction of ticks with a non-none decision",
    ))
    rows.append((
        "controlled_matrix_drop_rate",
        float(np.mean([r.drop_rate for r in reports])),
        "mean shed fraction under control",
    ))
    return rows
