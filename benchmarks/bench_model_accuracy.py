"""Fig. 6 + Fig. 7 reproduction: allocation quality and model-vs-measured
sojourn times across candidate configurations (VLD-like and FPD-like).

Fig. 6 claim: the DRS-recommended allocation attains the smallest
measured sojourn time (and smallest std) among neighbouring configs.
Fig. 7 claim: estimated vs measured points are monotone (model ranks
configurations correctly), with mild underestimation.
"""

from __future__ import annotations

import numpy as np

from repro.api import AppGraph, Edge, OpDef
from repro.core import assign_processors


def vld_graph() -> AppGraph:
    return AppGraph.chain(
        [("extract", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=13.0
    )


def fpd_graph() -> AppGraph:
    # generate -> detect (self-loop, leak .7) -> report; lam0 such that
    # detect is the heavy operator like the paper's (6:13:3).
    return AppGraph(
        [OpDef("generate", 4.0), OpDef("detect", 3.0), OpDef("report", 12.0)],
        [
            Edge("generate", "detect"),
            Edge("detect", "detect", multiplicity=0.3),
            Edge("detect", "report", multiplicity=0.7),
        ],
        {"generate": 16.0},
    )


def run_app(
    name: str,
    graph: AppGraph,
    k_max: int,
    configs: list[tuple[int, ...]],
    *,
    horizon: float = 800.0,
    warmup: float = 80.0,
):
    rows = []
    top = graph.topology()
    session = graph.bind("des", horizon=horizon, warmup=warmup)
    best = assign_processors(top, k_max)
    star = tuple(best.k.tolist())
    all_cfgs = list(configs)
    if star not in all_cfgs:
        all_cfgs.append(star)
    measured = {}
    for i, c in enumerate(all_cfgs):
        est = top.expected_sojourn(list(c))
        sim = session.simulate(list(c), seed=100 + i)
        measured[c] = sim.mean_sojourn
        mark = "*DRS*" if c == star else ""
        rows.append((
            f"{name}_{':'.join(map(str, c))}",
            sim.mean_sojourn * 1e3,
            f"ms measured | est {est*1e3:.1f} ms | std {sim.std_sojourn*1e3:.1f} ms {mark}",
        ))
    # Fig 6 check: DRS config is measured-best (within sim noise)
    best_measured = min(measured, key=measured.get)
    ok = measured[star] <= measured[best_measured] * 1.08
    rows.append((f"{name}_drs_is_best", float(ok), f"DRS {star} vs best {best_measured}"))
    # Fig 7 check: rank correlation between model and measurement
    cfgs = list(measured)
    est_rank = np.argsort(np.argsort([top.expected_sojourn(list(c)) for c in cfgs]))
    meas_rank = np.argsort(np.argsort([measured[c] for c in cfgs]))
    rho = float(np.corrcoef(est_rank, meas_rank)[0, 1])
    rows.append((f"{name}_rank_correlation", rho, "spearman est-vs-measured"))
    return rows


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    # smoke: fewer candidate configs and a short horizon — drift gate, not
    # a figure run (sim noise makes the fig-6 "best" check unreliable here,
    # but rank correlation and row shape still guard regressions).
    horizon, warmup = (200.0, 20.0) if smoke else (800.0, 80.0)
    vld_cfgs = [(10, 11, 1), (9, 12, 1), (11, 10, 1), (8, 12, 2), (12, 8, 2), (7, 13, 2)]
    fpd_cfgs = [(6, 13, 3), (7, 12, 3), (5, 14, 3), (6, 12, 4), (8, 11, 3)]
    if smoke:
        vld_cfgs, fpd_cfgs = vld_cfgs[:3], fpd_cfgs[:3]
    rows = []
    rows += run_app("vld", vld_graph(), 22, vld_cfgs, horizon=horizon, warmup=warmup)
    rows += run_app("fpd", fpd_graph(), 22, fpd_cfgs, horizon=horizon, warmup=warmup)
    return rows


def main() -> None:
    for name, val, note in run():
        print(f"{name},{val:.4f},{note}")


if __name__ == "__main__":
    main()
