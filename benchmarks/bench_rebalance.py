"""Fig. 9 + Fig. 10 reproduction: live re-scheduling behaviour.

Fig. 9: runs starting from non-optimal allocations converge to the DRS
optimum when rebalancing is enabled mid-run, with a small disruption.
Fig. 10: ExpA (T_max tight, K grows via the negotiator) and ExpB (T_max
loose, machines released) — resource adaptation in both directions.

The VLD-shape application is declared once as an AppGraph; the DES runs
through ``graph.bind("des")`` (no hand-built routing matrices or
arrival/service lists).
"""

from __future__ import annotations

import numpy as np

from repro.api import AppGraph
from repro.core import (
    Machine,
    Negotiator,
    ResourcePool,
    assign_processors,
    min_processors,
)


def _run_with_rebalance(graph, k0, k1, t_switch=400.0, horizon=800.0, pause=2.0, seed=0):
    session = graph.bind("des", seed=seed, horizon=horizon, warmup=0.0)
    res = session.simulate(
        k0,
        rebalance_to=k1,
        rebalance_at=t_switch if k1 is not None else None,
        pause=pause,
    )
    ts = np.array([t for t, _ in res.sojourn_series])
    sj = np.array([s for _, s in res.sojourn_series])
    before = float(sj[(ts > 50) & (ts < t_switch)].mean())
    after = float(sj[ts > t_switch + 50].mean()) if (ts > t_switch + 50).any() else np.nan
    spike = float(sj[(ts >= t_switch) & (ts <= t_switch + 30)].max()) if (
        (ts >= t_switch) & (ts <= t_switch + 30)
    ).any() else np.nan
    return before, after, spike


def run() -> list[tuple[str, float, str]]:
    rows = []
    graph = AppGraph.chain([("extract", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=13.0)
    top = graph.topology()
    best = assign_processors(top, 22).k

    # Fig 9: three initial allocations, rebalance at t=400
    for i, k0 in enumerate(([8, 12, 2], [11, 9, 2], list(best))):
        k1 = None if list(k0) == list(best) else best
        before, after, spike = _run_with_rebalance(graph, k0, k1, seed=20 + i)
        tag = "already-optimal" if k1 is None else "rebalanced"
        rows.append((f"fig9_init_{':'.join(map(str, k0))}_before", before * 1e3, "ms"))
        rows.append((
            f"fig9_init_{':'.join(map(str, k0))}_after", (after if k1 is not None else before) * 1e3,
            f"ms ({tag}; transient max {spike*1e3:.0f} ms)" if not np.isnan(spike) else f"ms ({tag})",
        ))

    # Fig 10 ExpA: T_max=0.73 unreachable at K=17 -> negotiator adds a machine
    pool = ResourcePool([Machine(f"m{i}", 5) for i in range(10)])
    neg = Negotiator(pool)
    neg.ensure(17)
    k17 = assign_processors(top, 17).k
    need = min_processors(top, 0.73)
    neg.ensure(need.total)
    k_new = assign_processors(top, neg.k_max).k
    before, after, _ = _run_with_rebalance(graph, k17, k_new, seed=31)
    rows.append(("fig10_expA_before_K17", before * 1e3, f"ms with k={k17.tolist()}"))
    rows.append((
        "fig10_expA_after_scaleout", after * 1e3,
        f"ms with k={k_new.tolist()} (K_max {17}->{neg.k_max}); T_max=730 ms "
        f"{'met' if after <= 0.73 else 'MISSED'}",
    ))

    # Fig 10 ExpB: T_max=2.0 loose at K=22 -> release machines
    pool_b = ResourcePool([Machine(f"m{i}", 5) for i in range(10)])
    neg_b = Negotiator(pool_b)
    neg_b.ensure(22)
    k22 = assign_processors(top, 22).k
    need_b = min_processors(top, 2.0)
    neg_b.ensure(need_b.total)
    k_small = assign_processors(top, neg_b.k_max).k
    before, after, _ = _run_with_rebalance(graph, k22, k_small, seed=32)
    rows.append(("fig10_expB_before_K22", before * 1e3, f"ms with k={k22.tolist()}"))
    rows.append((
        "fig10_expB_after_scalein", after * 1e3,
        f"ms with k={k_small.tolist()} (K_max 22->{neg_b.k_max}); T_max=2000 ms "
        f"{'met' if after <= 2.0 else 'MISSED'}",
    ))
    return rows


def main() -> None:
    for name, val, note in run():
        print(f"{name},{val:.2f},{note}")


if __name__ == "__main__":
    main()
