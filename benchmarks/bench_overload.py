"""Flash-crowd overload reproduction (paper Fig. 9/10 mid-run shifts).

Three parts (DESIGN.md §11):

* **A — DES flash crowd**: a 2x external-rate step on a bounded-queue
  chain under each :class:`~repro.streaming.overload.OverloadPolicy`,
  versus the unbounded baseline.  Bounded queues keep the backlog (and
  therefore the post-burst recovery time) flat; the unbounded baseline
  absorbs the whole burst into queueing delay and takes far longer to
  drain back under the target.
* **B — engine vs DES drop agreement**: the same AppGraph, deterministic
  arrivals and service, run live (worker threads, wall clock) and
  simulated; per-operator drop rates must agree within ~10%.
* **C — scheduler overload path**: a live engine session driven at 2x its
  capacity; the first tick must emit the ``"overloaded"`` decision (the
  negotiator leases immediately), after which measured sojourn recovers
  below T_max.
"""

from __future__ import annotations

import time

import numpy as np

from repro.api import AppGraph, OpDef
from repro.core import Machine, Negotiator, ResourcePool, SchedulerConfig
from repro.streaming.des import NetworkSimulator, SimConfig
from repro.streaming.overload import OVERLOAD_POLICIES

# --------------------------------------------------------------------- #
# Part A: DES flash crowd — 2x rate step under each policy
# --------------------------------------------------------------------- #
BASE_RATE = 6.0  # tuples/s; step to 2x mid-run
T_TARGET = 1.0  # recovery threshold on windowed mean sojourn (seconds)


def _flash_crowd_sim(queue_capacity, policy, seed=0):
    """Chain extract(mu=4, k=2) -> agg(mu=40, k=1); rho=0.75 at the base
    rate, 1.5 during the burst (t in [300, 500))."""
    graph = AppGraph.chain([("extract", 4.0), ("agg", 40.0)], lam0=BASE_RATE)
    top = graph.topology()
    sim = NetworkSimulator(
        top,
        [2, 1],
        config=SimConfig(
            seed=seed,
            horizon=900.0,
            warmup=0.0,
            queue_capacity=queue_capacity,
            overload_policy=policy,
        ),
    )
    sim.schedule_arrival_change(300.0, 0, 2 * BASE_RATE)
    sim.schedule_arrival_change(500.0, 0, BASE_RATE)
    return sim.run()


def _recovery_time(res, t_end=500.0, window=20.0):
    """First time after the burst when the windowed mean sojourn stays
    below T_TARGET (np.nan if it never recovers within the horizon)."""
    ts = np.array([t for t, _ in res.sojourn_series])
    sj = np.array([s for _, s in res.sojourn_series])
    for t in np.arange(t_end, ts.max() - window if ts.size else t_end, window / 2):
        sel = (ts >= t) & (ts < t + window)
        if sel.any() and float(sj[sel].mean()) < T_TARGET:
            return float(t - t_end)
    return float("nan")


def _part_a(rows):
    baseline = _flash_crowd_sim(queue_capacity=None, policy="shed-newest", seed=1)
    rows.append((
        "flashcrowd_unbounded_recovery_s", _recovery_time(baseline),
        f"s after burst end; max backlog {int(baseline.per_op_max_backlog.max())} "
        f"tuples, p95 sojourn {baseline.p95_sojourn:.2f}s (baseline)",
    ))
    for policy in OVERLOAD_POLICIES:
        res = _flash_crowd_sim(queue_capacity=50, policy=policy, seed=1)
        drop_rate = float(res.per_op_drop_rate.sum())
        rows.append((
            f"flashcrowd_{policy}_recovery_s", _recovery_time(res),
            f"s after burst end; cap=50, max backlog "
            f"{int(res.per_op_max_backlog.max())}, dropped {res.dropped} "
            f"({drop_rate:.2f}/s), shed roots {res.shed_roots}, "
            f"completed {res.completed}",
        ))


# --------------------------------------------------------------------- #
# Part B: engine vs DES per-op drop-rate agreement on one AppGraph
# --------------------------------------------------------------------- #
SERVICE_S = 0.05  # engine op busy time -> mu = 20/s
OFFER_RATE = 40.0  # 2x capacity at k=1
CAPACITY = 4  # queue bound


def _agreement_graph():
    def work(_x):
        time.sleep(SERVICE_S)
        return []

    return AppGraph(
        [OpDef("work", mu=1.0 / SERVICE_S, fn=work, service_kind="deterministic")],
        [],
        {"work": OFFER_RATE},
        arrival_kind="deterministic",
    )


def _part_b(rows):
    graph = _agreement_graph()
    # Live engine: deterministic injection at OFFER_RATE for ~3 s.
    session = graph.bind(
        "engine", queue_capacity=CAPACITY, overload_policy="shed-newest"
    )
    session.start({"work": 1})
    period = 1.0 / OFFER_RATE
    t0 = time.perf_counter()
    offered = 0
    while time.perf_counter() - t0 < 3.0:
        session.inject(offered)
        offered += 1
        target = t0 + offered * period
        if (sleep_for := target - time.perf_counter()) > 0:
            time.sleep(sleep_for)
    elapsed = time.perf_counter() - t0
    session.drain(timeout=10.0)
    session.stop()
    eng_drop_rate = session.drop_counts()["work"] / elapsed
    # DES: same graph, same policy, 100 simulated seconds.
    des = graph.bind(
        "des", queue_capacity=CAPACITY, overload_policy="shed-newest",
        horizon=100.0, warmup=5.0,
    ).simulate([1])
    des_drop_rate = float(des.per_op_drop_rate[0])
    ratio = eng_drop_rate / des_drop_rate if des_drop_rate > 0 else float("nan")
    rows.append((
        "drop_agreement_engine_per_s", eng_drop_rate,
        f"engine sheds/s at offered {OFFER_RATE}/s, capacity ~{1/SERVICE_S:.0f}/s",
    ))
    rows.append((
        "drop_agreement_des_per_s", des_drop_rate,
        f"DES sheds/s on the same AppGraph (ratio {ratio:.3f}; "
        f"{'within' if abs(ratio - 1) <= 0.10 else 'OUTSIDE'} 10%)",
    ))


# --------------------------------------------------------------------- #
# Part C: live scheduler — "overloaded" decision, then recovery < T_max
# --------------------------------------------------------------------- #
T_MAX = 0.5


def _part_c(rows):
    def work(_x):
        time.sleep(SERVICE_S)
        return []

    graph = AppGraph(
        [OpDef("work", mu=1.0 / SERVICE_S, fn=work)], [], {"work": OFFER_RATE}
    )
    pool = ResourcePool([Machine(f"m{i}", 1) for i in range(8)])
    negotiator = Negotiator(pool)
    negotiator.ensure(1)
    session = graph.bind(
        "engine",
        queue_capacity=CAPACITY,
        overload_policy="shed-newest",
        config=SchedulerConfig(t_max=T_MAX, min_improvement=0.01),
        negotiator=negotiator,
    )
    session.start({"work": 1})  # capacity 20/s vs 40/s offered
    period = 1.0 / OFFER_RATE

    def drive(seconds):
        t0 = time.perf_counter()
        sent = 0
        while time.perf_counter() - t0 < seconds:
            session.inject(sent)
            sent += 1
            target = t0 + sent * period
            if (dt := target - time.perf_counter()) > 0:
                time.sleep(dt)

    drive(2.0)
    k_before = negotiator.k_max
    decision = session.tick()
    k_after = negotiator.k_max
    rows.append((
        "scheduler_overload_k_max", k_after,
        f"decision '{decision.action}' (expect 'overloaded'); k_max "
        f"{k_before} -> {k_after}, allocation {session.allocation}",
    ))
    # Post-scale-out: same offered load, now feasible; measure recovery.
    n_before = len(session.completed_sojourns)
    drive(2.0)
    session.drain(timeout=10.0)
    session.tick()
    recovered = session.completed_sojourns[n_before:]
    tail = float(np.mean(recovered[len(recovered) // 2 :])) if recovered else float("nan")
    session.stop()
    rows.append((
        "scheduler_overload_recovered_sojourn_s", tail,
        f"measured mean sojourn after scale-out (T_max {T_MAX}s "
        f"{'met' if tail < T_MAX else 'MISSED'})",
    ))


def run() -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    _part_a(rows)
    _part_b(rows)
    _part_c(rows)
    return rows


def main() -> None:
    for name, val, note in run():
        print(f"{name},{val},{note}")


if __name__ == "__main__":
    main()
