"""Batched control-plane benchmark: fused jit decide vs the per-scenario
Python loop (DESIGN.md §14).

The claim: extracting the decision math out of ``DRSScheduler`` into the
batched controller turns B independent measure -> model -> rebalance
loops from B Python interpreter walks per tick (tables, greedy, gates,
object plumbing — the per-query scheduling overhead model-driven
schedulers exist to amortize) into ONE compiled program over ``[B, N]``
arrays.  Rows:

* ``decide_scalar_seconds_B{B}`` — wall-clock for one control tick driven
  through B per-scenario ``DRSScheduler.tick_from`` calls (the PR-4
  ScenarioRunner structure);
* ``decide_fused_seconds_B{B}`` — the same B decisions through the jit
  ``make_decide_jax`` program (post-compile, per-call mean);
* ``speedup_fused_vs_scalar_B64`` — the acceptance gate: >= 20x at B=64;
* ``fused_loop_ticks_per_second_B{B}`` — whole fused simulate -> measure
  -> decide -> apply scan throughput (ticks/s across the batch);
* ``fused_loop_sharded_ticks_per_second_B{B}_D{D}`` — the mesh story
  (DESIGN.md §16): the same fused loop at fleet scale (B=4096 full run,
  B=64 smoke) with the batch axis sharded over D emulated host devices,
  measured in a subprocess because ``XLA_FLAGS=
  --xla_force_host_platform_device_count`` must be set before jax
  imports.  A ``_pinned_..._D1`` twin row runs the identical shard_map
  program on a 1-device mesh; ``sharded_vs_pinned_ratio`` reports the
  device-parallel speedup (only meaningful when the host has cores to
  back the emulated devices — the note records the core count);
* ``gain_topr_interpret_parity`` — Pallas top-R kernel vs jnp oracle in
  interpret mode on CPU (1.0 = exact take-for-take agreement);
* ``decide_dense_ticks_per_second_B{B}`` /
  ``decide_compacted_ticks_per_second_B{B}_trig{F}pct`` — the §18
  trigger-gated sparse decide vs the dense decide on a diurnal-zoo
  static stack tiled to fleet extent (B=4096 full / B=256 smoke, plus a
  B=10000 full-run row), with the trigger rate pinned by perturbing
  exactly ``F%`` of the lanes' inputs per tick.  Every compacted tick's
  decisions are asserted **bitwise identical** to the dense decide
  before it is timed (hard fail, smoke included; E[T] diagnostics to the
  mesh tests' ~1-ulp rtol); ``compacted_vs_dense_speedup_B4096_
  trig10pct`` is the acceptance gate (>= 3x, full runs only — smoke
  extents are too small for the ladder to pay);
* ``compacted_peak_live_bytes_B{B}`` — device-reported peak live bytes
  after the compacted sweep via ``jax.local_devices()[0].memory_stats()``
  (``-1.0`` on CPU hosts, which report no allocator stats).
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

from repro.api.session import ScenarioRunner
from repro.core import controller as ctl
from repro.core.measurer import MeasurementSnapshot
from repro.core.scheduler import DRSScheduler, SchedulerConfig


def _scalar_schedulers(runner: ScenarioRunner):
    """The pre-extraction structure: one DRSScheduler object per scenario."""
    scheds = []
    for bi, s in enumerate(runner.scenarios):
        scaling, group_alpha = s.graph.scaling_lists()
        scheds.append(DRSScheduler(
            s.graph.names,
            s.graph.routing_matrix(),
            runner.k[bi, : s.graph.n].copy(),
            SchedulerConfig(k_max=s.k_max, t_max=s.t_max, allocator=s.allocator),
            scaling=scaling,
            group_alpha=group_alpha,
        ))
    return scheds


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    import jax.numpy as jnp

    rows: list[tuple[str, float, str]] = []
    b = 16 if smoke else 64
    reps = 3 if smoke else 10
    horizon = 30.0
    from repro.streaming.scenarios import scenario_matrix

    scens = [
        s.with_(negotiated=False)
        for s in scenario_matrix(b, seed=5, horizon=horizon, warmup=5.0, dt=0.05)
    ]
    runner = ScenarioRunner(scens, tick_interval=5.0, backend="numpy", fused=False)
    # One real simulated window -> the measurement both paths decide on.
    w = runner.sim.step_window(runner.k, runner._steps_per_tick)
    meas, _ = runner._window_measurement(w)
    rows.append(("controller_scenarios", float(b), f"scenarios, N={runner.arrays.n}"))

    # --- per-scenario Python loop (the PR-4 structure) ------------------- #
    scheds = _scalar_schedulers(runner)
    k0 = runner.k.copy()
    snaps = [
        MeasurementSnapshot.from_rates(
            meas.lam_hat[bi, : s.graph.n], meas.mu_hat[bi, : s.graph.n],
            float(meas.lam0_hat[bi]), float(meas.sojourn_hat[bi]), 0.0,
            drop_hat=meas.drop_hat[bi, : s.graph.n],
        )
        for bi, s in enumerate(runner.scenarios)
    ]
    from repro.core.allocator import InsufficientResourcesError
    from repro.core.jackson import UnstableTopologyError

    t_scalar = []
    for _ in range(reps):
        for bi, sched in enumerate(scheds):
            sched.k_current = k0[bi, : len(sched.names)].copy()
        t0 = time.perf_counter()
        for bi, sched in enumerate(scheds):
            try:
                sched.tick_from(snaps[bi], 0.0)
            except (InsufficientResourcesError, UnstableTopologyError):
                pass  # the runner's infeasible row (PR-4 semantics)
        t_scalar.append(time.perf_counter() - t0)
    scalar_s = float(np.median(t_scalar))
    rows.append((f"decide_scalar_seconds_B{b}", scalar_s,
                 "s per tick, B per-scenario DRSScheduler.tick_from"))

    # --- fused jit batch decide ------------------------------------------ #
    decide = ctl.make_decide_jax(runner.static, runner._params())
    args = (
        jnp.asarray(meas.lam_hat), jnp.asarray(meas.mu_hat),
        jnp.asarray(meas.drop_hat), jnp.asarray(meas.lam0_hat),
        jnp.asarray(k0),
    )
    out = decide(*args)  # compile
    out[1].block_until_ready()
    t_fused = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = decide(*args)
        out[1].block_until_ready()
        t_fused.append(time.perf_counter() - t0)
    fused_s = float(np.median(t_fused))
    rows.append((f"decide_fused_seconds_B{b}", fused_s,
                 "s per tick, one jit decide over the [B, N] stack"))
    rows.append((
        f"speedup_fused_vs_scalar_B{b}",
        scalar_s / max(fused_s, 1e-12),
        "x fused jit batch-decide vs per-scenario loop "
        "(acceptance: >= 20x at B=64)",
    ))

    # --- whole fused loop: simulate -> measure -> decide -> apply -------- #
    fused_runner = ScenarioRunner(scens, tick_interval=5.0, backend="jax")
    n_ticks = fused_runner.arrays.steps // fused_runner._steps_per_tick
    run_fn, _ = ctl.make_fused_loop(
        fused_runner.arrays, fused_runner.static, fused_runner._params(),
        steps_per_tick=fused_runner._steps_per_tick,
    )
    run_fn(fused_runner.k)["k_final"].block_until_ready()  # compile
    t0 = time.perf_counter()
    run_fn(fused_runner.k)["k_final"].block_until_ready()
    t_loop = time.perf_counter() - t0
    rows.append((
        f"fused_loop_ticks_per_second_B{b}",
        n_ticks * b / t_loop,
        f"scenario-ticks/s, {n_ticks} ticks x B={b} in one lax.scan program",
    ))
    base_tps = n_ticks * b / t_loop

    # --- sharded fused loop at fleet scale (subprocess: XLA_FLAGS) ------- #
    b_shard, d = (64, 2) if smoke else (4096, 8)
    info = _run_sharded_subprocess(b_shard, d, horizon, reps=2)
    sharded_tps = info["ticks_per_s_sharded"]
    pinned_tps = info["ticks_per_s_pinned"]
    rows.append((
        f"fused_loop_sharded_ticks_per_second_B{b_shard}_D{d}", sharded_tps,
        f"scenario-ticks/s, batch axis shard_map'd over {d} emulated host "
        f"devices ({info['n_ticks']} ticks)",
    ))
    rows.append((
        f"fused_loop_pinned_ticks_per_second_B{b_shard}_D1", pinned_tps,
        "same shard_map program on a 1-device mesh (the pinned baseline)",
    ))
    rows.append((
        f"sharded_vs_pinned_ratio_B{b_shard}",
        sharded_tps / max(pinned_tps, 1e-12),
        f"x D={d} mesh vs 1-device mesh; host has {os.cpu_count()} core(s) "
        "backing the emulated devices — parallel speedup needs real cores",
    ))
    rows.append((
        f"sharded_vs_B{b}_throughput_ratio",
        sharded_tps / max(base_tps, 1e-12),
        f"x B={b_shard} sharded aggregate scenario-ticks/s vs this run's "
        f"B={b} single-device row (ROADMAP's ~4.4k ticks/s reference)",
    ))

    # --- §18 trigger-gated compacted decide vs dense --------------------- #
    rows.extend(_compaction_rows(smoke))

    # --- gain_topr kernel parity (interpret mode on CPU) ----------------- #
    from repro.kernels.gain_topr import kernel as topr_kernel, ref as topr_ref

    rng = np.random.default_rng(7)
    cand = np.maximum(rng.normal(0.5, 1.0, (8, 6, 24)), 0.0).astype(np.float32)
    cand.sort(axis=-1)
    cand = cand[..., ::-1].copy()
    budget = rng.integers(0, 40, 8).astype(np.int32)
    want = np.asarray(topr_ref.gain_topr(jnp.asarray(cand), jnp.asarray(budget)))
    got = np.asarray(topr_kernel.gain_topr_pallas(
        jnp.asarray(cand), jnp.asarray(budget), interpret=True
    ))
    rows.append((
        "gain_topr_interpret_parity",
        float((want == got).all()),
        "Pallas top-R kernel == jnp oracle, interpret mode (1.0 = exact)",
    ))
    return rows


# --------------------------------------------------------------------------- #
# §18 compacted decide: tile a small diurnal-zoo static stack to fleet
# extent and pin the trigger rate by construction — the compacted decide
# triggers on exact input change, so perturbing exactly f*B lanes' lam
# rows per tick (by a factor that never repeats between consecutive
# ticks) reprices exactly those lanes plus any hot ones.
# --------------------------------------------------------------------------- #
def _tile_static(st: ctl.ControllerStatic, reps: int) -> ctl.ControllerStatic:
    from dataclasses import replace as _replace

    return _replace(
        st,
        base_routing=np.tile(st.base_routing, (reps, 1, 1)),
        group=np.tile(st.group, (reps, 1)),
        alpha=np.tile(st.alpha, (reps, 1)),
        active=np.tile(st.active, (reps, 1)),
        speed=np.tile(st.speed, (reps, 1)),
        n_ops=np.tile(st.n_ops, reps),
        names=st.names * reps,
    )


def _tile_params(pr: ctl.ControllerParams, reps: int) -> ctl.ControllerParams:
    from dataclasses import replace as _replace

    return _replace(
        pr,
        t_max=np.tile(pr.t_max, reps),
        k_max=np.tile(pr.k_max, reps),
        headroom=np.tile(pr.headroom, reps),
        scale_in_hysteresis=np.tile(pr.scale_in_hysteresis, reps),
        min_improvement=np.tile(pr.min_improvement, reps),
        horizon_seconds=np.tile(pr.horizon_seconds, reps),
        allocator=pr.allocator * reps,
    )


def _decide_tps(
    b: int, rates: tuple[float, ...], *, reps: int, gate_at: float | None
) -> list[tuple[str, float, str]]:
    """Compacted-vs-dense decide ticks/s rows at extent ``b``, one per
    trigger rate.  Asserts bitwise identity on every compacted tick and
    the >= 3x gate at ``gate_at`` (None skips the gate — smoke extents)."""
    import jax
    import jax.numpy as jnp

    from repro.streaming.scenarios import scenario_matrix

    zoo = [
        s.with_(negotiated=False)
        for s in scenario_matrix(16, seed=9, horizon=20.0, warmup=5.0, dt=0.05)
    ]
    runner = ScenarioRunner(zoo, tick_interval=5.0, backend="numpy", fused=False)
    assert b % 16 == 0, b
    st = _tile_static(runner.static, b // 16)
    pr = _tile_params(runner._params(), b // 16)
    n = st.n
    rng = np.random.default_rng(3)
    lam = np.abs(rng.normal(2.0, 0.5, (b, n)))
    mu = np.abs(rng.normal(6.0, 0.5, (b, n))) + 1.0
    drop = np.zeros((b, n))
    lam0 = np.abs(rng.normal(2.0, 0.5, b))
    k = np.where(st.active, 2, 0).astype(np.int64)

    dense = ctl.make_decide_jax(st, pr)
    comp = ctl.make_decide_jax(st, pr, compact=True)
    rows: list[tuple[str, float, str]] = []
    dense_tps = None
    for rate in rates:
        n_trig = int(round(rate * b))
        # Factor cycle length 7 is coprime with everything the loop does,
        # so consecutive ticks never present a triggered lane with the
        # same lam row (which would memoize it quiet).
        lam_ticks = []
        for t in range(reps + 1):
            lt = lam.copy()
            lt[:n_trig] *= 1.0 + 0.01 * ((t % 7) + 1)
            lam_ticks.append(jnp.asarray(lt))
        d_args = lambda lt: (lt, jnp.asarray(mu), jnp.asarray(drop),
                             jnp.asarray(lam0), jnp.asarray(k))
        dense_outs = [dense(*d_args(lt)) for lt in lam_ticks]
        dense_outs[0][1].block_until_ready()
        if dense_tps is None:
            t0 = time.perf_counter()
            for lt in lam_ticks[1:]:
                dense(*d_args(lt))[1].block_until_ready()
            dense_tps = reps / (time.perf_counter() - t0)
            rows.append((f"decide_dense_ticks_per_second_B{b}", dense_tps,
                         f"dense jit decide, B={b} diurnal-zoo tile"))
        cache = comp.init_cache()
        out, _, cache = comp(*d_args(lam_ticks[0]), cache)  # cold: dense-cost
        out[1].block_until_ready()
        t0 = time.perf_counter()
        comp_outs = []
        for lt in lam_ticks[1:]:
            out, _, cache = comp(*d_args(lt), cache)
            comp_outs.append(out)
        comp_outs[-1][1].block_until_ready()
        comp_tps = reps / (time.perf_counter() - t0)
        # Bit-identity before the number is reported: a fast wrong decide
        # is worthless.  Hard fail — smoke included.  Decisions (code,
        # k_next, applied) are bitwise; the E[T] diagnostics get the mesh
        # tests' ~1-ulp rtol (XLA reassociates lane reductions at
        # compacted widths — tests/test_compaction.py).
        for ti, (want, got) in enumerate(zip(dense_outs[1:], comp_outs)):
            for oi in (0, 1, 4):
                if not np.array_equal(np.asarray(want[oi]), np.asarray(got[oi])):
                    raise AssertionError(
                        f"compacted decide diverged from dense at B={b}, "
                        f"trigger rate {rate:.0%}, tick {ti}, out[{oi}]"
                    )
            for oi in (2, 3):
                np.testing.assert_allclose(
                    np.asarray(want[oi]), np.asarray(got[oi]), rtol=1e-6,
                    err_msg=f"B={b} rate={rate} tick={ti} out[{oi}]",
                )
        pct = int(round(rate * 100))
        rows.append((
            f"decide_compacted_ticks_per_second_B{b}_trig{pct}pct", comp_tps,
            f"§18 compacted decide, {n_trig}/{b} lanes triggered per tick "
            "(bitwise == dense, asserted)",
        ))
        speedup = comp_tps / max(dense_tps, 1e-12)
        rows.append((
            f"compacted_vs_dense_speedup_B{b}_trig{pct}pct", speedup,
            "x compacted vs dense ticks/s"
            + (" (acceptance: >= 3x)" if gate_at == rate else ""),
        ))
        if gate_at == rate and speedup < 3.0:
            raise AssertionError(
                f"compaction gate regressed: {speedup:.2f}x < 3x at "
                f"B={b}, {rate:.0%} trigger rate"
            )
    ms = jax.local_devices()[0].memory_stats() or {}
    rows.append((
        f"compacted_peak_live_bytes_B{b}",
        float(ms.get("peak_bytes_in_use", -1.0)),
        "device peak live bytes after the compacted sweep "
        "(-1.0: backend reports no allocator stats, e.g. CPU)",
    ))
    return rows


def _compaction_rows(smoke: bool) -> list[tuple[str, float, str]]:
    rates = (0.02, 0.10, 0.50)
    if smoke:
        return _decide_tps(256, rates, reps=3, gate_at=None)
    rows = _decide_tps(4096, rates, reps=8, gate_at=0.10)
    rows += _decide_tps(10_000, (0.10,), reps=4, gate_at=None)
    return rows


# --------------------------------------------------------------------------- #
# Sharded rows run out-of-process: the emulated-device flag must be in
# XLA_FLAGS before jax ever imports, which this (already-jax-importing)
# process cannot retrofit.
# --------------------------------------------------------------------------- #
def _run_sharded_subprocess(b: int, d: int, horizon: float, reps: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={d}"
    ).strip()
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, __file__, "--sharded-worker",
         str(b), str(d), str(horizon), str(reps)],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded bench worker failed (rc={proc.returncode}):\n"
            f"{proc.stderr[-2000:]}"
        )
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _sharded_worker(argv: list[str]) -> None:
    b, d, horizon, reps = (
        int(argv[0]), int(argv[1]), float(argv[2]), int(argv[3])
    )
    import jax

    from repro.distributed.sharding import fleet_mesh
    from repro.streaming.scenarios import scenario_matrix

    scens = [
        s.with_(negotiated=False)
        for s in scenario_matrix(b, seed=5, horizon=horizon, warmup=5.0, dt=0.05)
    ]
    runner = ScenarioRunner(scens, tick_interval=5.0, backend="jax")
    out: dict = {"b": b, "devices": len(jax.devices())}
    for tag, nd in (("sharded", d), ("pinned", 1)):
        loop, n_ticks = ctl.make_fused_loop(
            runner.arrays, runner.static, runner._params(),
            steps_per_tick=runner._steps_per_tick, mesh=fleet_mesh(nd),
        )
        np.asarray(loop(runner.k)["k_final"])  # compile + warm
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            loop(runner.k)["k_final"].block_until_ready()
            ts.append(time.perf_counter() - t0)
        out[f"ticks_per_s_{tag}"] = n_ticks * b / min(ts)
        out["n_ticks"] = n_ticks
    print(json.dumps(out))


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--sharded-worker":
        _sharded_worker(sys.argv[2:])
    else:
        for _name, _val, _note in run(smoke="--smoke" in sys.argv[1:]):
            print(f"{_name},{_val},{_note}")
