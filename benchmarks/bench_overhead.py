"""Table II reproduction: DRS computation overhead vs K_max.

The paper reports scheduling cost growing linearly in K_max (0.083 ms at
K=12 to 1.25 ms at K=192) and a constant measurement-processing cost.
We time three Program-(4) solvers on the VLD topology:

* the naive Algorithm-1 transcription (the paper's algorithm),
* the heap greedy (PR-1's beyond-paper win — but still O(K) *scalar*
  Erlang recursions, each O(k), so per-tick cost grows ~K^2 in Python),
* the batched gain-table greedy (this PR, DESIGN.md §12: one vectorized
  Erlang pass + a top-R selection; bit-identical allocations),

plus the measurer pull path, and extend K_max to 4096 to show the control
plane stays microsecond-to-millisecond at pod scale.  The
``speedup_table_vs_scalar_K1024`` row is the acceptance gate for the
batched core: >= 5x lower per-tick scheduling latency at K_max = 1024
than the scalar (heap) path.  A ``fleet_plan_*`` row times the
multi-tenant FleetPlanner end-to-end (M tenants, one shared pool).

Naive is quadratic-plus in K and dominates wall-clock, so it is only
timed up to K=192 in ``--smoke`` mode (K=1024 full).
"""

from __future__ import annotations

import time

from repro.api import AppGraph
from repro.core import (
    FleetPlanner,
    Measurer,
    Tenant,
    assign_processors,
    assign_processors_naive,
    assign_processors_table,
)


def time_fn(fn, *args, repeat=200) -> float:
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn(*args)
    return (time.perf_counter() - t0) / repeat


def _vld_top(k_max: int):
    # Scale the topology load with K so the min-feasible allocation stays
    # a constant fraction of the budget (paper keeps lam/mu fixed and the
    # allocation saturates; scaling matches their linear-growth regime).
    lam0 = 13.0 * k_max / 22.0
    return AppGraph.chain(
        [("extract", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=lam0
    ).topology()


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    naive_cap = 192 if smoke else 1024
    sweep = (12, 24, 48, 96, 192, 1024) if smoke else (12, 24, 48, 96, 192, 1024, 4096)
    t_heap_1024 = t_table_1024 = None
    for k_max in sweep:
        top = _vld_top(k_max)
        repeat = 5 if k_max >= 1024 else 20
        if k_max <= naive_cap:
            t_naive = time_fn(assign_processors_naive, top, k_max, repeat=repeat)
            rows.append(
                (f"scheduling_naive_K{k_max}", t_naive * 1e6, "us (paper Algorithm 1)")
            )
        t_heap = time_fn(assign_processors, top, k_max, repeat=repeat)
        t_table = time_fn(assign_processors_table, top, k_max, repeat=repeat)
        rows.append((f"scheduling_heap_K{k_max}", t_heap * 1e6, "us (scalar heap)"))
        rows.append(
            (f"scheduling_table_K{k_max}", t_table * 1e6, "us (batched gain table)")
        )
        if k_max == 1024:
            t_heap_1024, t_table_1024 = t_heap, t_table
    if t_heap_1024 and t_table_1024:
        rows.append((
            "speedup_table_vs_scalar_K1024",
            t_heap_1024 / t_table_1024,
            "x (acceptance: >= 5x)",
        ))

    # Multi-tenant planner: M graphs against one shared pool, per-tick cost.
    n_tenants = 4 if smoke else 8
    pool = 64 * n_tenants
    tenants = [
        Tenant(
            name=f"t{i}",
            graph=AppGraph.chain(
                [(f"e{i}", 2.0), (f"m{i}", 5.0), (f"a{i}", 50.0)],
                lam0=13.0 * (1.0 + 0.1 * i),
            ),
            t_max=2.0,
        )
        for i in range(n_tenants)
    ]
    planner = FleetPlanner(tenants, pool)
    t_fleet = time_fn(planner.plan, repeat=3 if smoke else 10)
    rows.append((
        f"fleet_plan_M{n_tenants}_K{pool}",
        t_fleet * 1e3,
        "ms per cross-tenant plan (Programs 4+6, merged gain tables)",
    ))

    # measurement processing (pull of 25 probes, paper's 'Measurement' row)
    m = Measurer([f"op{i}" for i in range(3)], n_m=10)
    probes = [m.new_probe(f"op{i % 3}") for i in range(25)]
    m.pull(0.0)
    for p in probes:
        p.on_enqueue(100)
        for _ in range(100):
            p.on_processed(0.01)

    def pull():
        m.pull(time.time())

    rows.append(("measurement_pull_25probes", time_fn(pull, repeat=200) * 1e6, "us"))
    return rows


def main() -> None:
    for name, us, note in run():
        print(f"{name},{us:.2f},{note}")


if __name__ == "__main__":
    main()
