"""Table II reproduction: DRS computation overhead vs K_max.

The paper reports scheduling cost growing linearly in K_max (0.083 ms at
K=12 to 1.25 ms at K=192) and a constant measurement-processing cost.
We time both our naive Algorithm-1 transcription (the paper's algorithm)
and the heap allocator (beyond-paper, O((K-K0) log N)), plus the measurer
pull path, on the VLD topology — and extend K_max to 4096 to show the
control plane stays micro-second-scale at pod scale (DESIGN.md §8).
"""

from __future__ import annotations

import time

from repro.api import AppGraph
from repro.core import Measurer, assign_processors, assign_processors_naive


def time_fn(fn, *args, repeat=200) -> float:
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn(*args)
    return (time.perf_counter() - t0) / repeat


def run() -> list[tuple[str, float, str]]:
    rows = []
    # Scale the topology load with K so the min-feasible allocation stays
    # a constant fraction of the budget (paper keeps lam/mu fixed and the
    # allocation saturates; scaling matches their linear-growth regime).
    for k_max in (12, 24, 48, 96, 192, 1024, 4096):
        lam0 = 13.0 * k_max / 22.0
        top = AppGraph.chain(
            [("extract", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=lam0
        ).topology()
        t_naive = time_fn(assign_processors_naive, top, k_max, repeat=20)
        t_heap = time_fn(assign_processors, top, k_max, repeat=20)
        rows.append((f"scheduling_naive_K{k_max}", t_naive * 1e6, "us (paper Algorithm 1)"))
        rows.append((f"scheduling_heap_K{k_max}", t_heap * 1e6, "us (heap variant)"))
    # measurement processing (pull of 25 probes, paper's 'Measurement' row)
    m = Measurer([f"op{i}" for i in range(3)], n_m=10)
    probes = [m.new_probe(f"op{i % 3}") for i in range(25)]
    m.pull(0.0)
    for p in probes:
        p.on_enqueue(100)
        for _ in range(100):
            p.on_processed(0.01)

    def pull():
        m.pull(time.time())

    rows.append(("measurement_pull_25probes", time_fn(pull, repeat=200) * 1e6, "us"))
    return rows


def main() -> None:
    for name, us, note in run():
        print(f"{name},{us:.2f},{note}")


if __name__ == "__main__":
    main()
