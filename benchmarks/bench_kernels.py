"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference on CPU.

Wall time in interpret mode is NOT TPU performance — the deliverable here
is (a) correctness at benchmark shapes and (b) the arithmetic-intensity
table each kernel is designed around (FLOPs vs bytes from the BlockSpec
tiling), which is what transfers to the TPU roofline.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def timeit(fn, *args, repeat=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat, out


def run() -> list[tuple[str, float, str]]:
    rows = []
    key = jax.random.PRNGKey(0)

    # erlang_c: the analytic core's hot recurrence (DESIGN.md §12)
    from repro.kernels.erlang_c import kernel as ek, ref as eref

    a = jnp.linspace(0.5, 256.0, 128, dtype=jnp.float32)
    t_ref, want = timeit(lambda a: eref.erlang_b_table(a, k_hi=512), a)
    t_k, got = timeit(
        lambda a: ek.erlang_b_table_pallas(a, k_hi=512, interpret=True), a
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)
    rows.append(("erlang_b_table_ref", t_ref * 1e6, "us lax.scan, 128 lanes x k=512"))
    rows.append(("erlang_b_table_pallas_interp", t_k * 1e6, "us interpret (correctness run)"))

    # l2_match: the paper's matcher bolt
    from repro.kernels.l2_match import kernel as lk, ref as lref

    m, n, d = 256, 128, 64
    a = jax.random.normal(key, (m, d))
    b = jax.random.normal(key, (n, d))
    t_ref, want = timeit(jax.jit(lref.pairwise_sq_l2), a, b)
    t_k, got = timeit(
        lambda a, b: lk.pairwise_sq_l2_pallas(a, b, interpret=True), a, b
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    flops = 2 * m * n * d
    bytes_ = 4 * (m * d + n * d + m * n)
    rows.append(("l2_match_ref", t_ref * 1e6, f"us jnp ({flops/bytes_:.1f} flop/byte)"))
    rows.append(("l2_match_pallas_interp", t_k * 1e6, "us interpret (correctness run)"))

    # flash attention
    from repro.kernels.flash_attention import kernel as fk, ref as fref

    bb, h, s, dh = 1, 4, 256, 64
    q = jax.random.normal(key, (bb, h, s, dh))
    kk = jax.random.normal(key, (bb, h, s, dh))
    v = jax.random.normal(key, (bb, h, s, dh))
    t_ref, want = timeit(jax.jit(lambda q, k, v: fref.attention(q, k, v)), q, kk, v)
    # The interpret-mode WALL-CLOCK row is deliberately gone (ROADMAP
    # kernels item): the online-softmax recurrence serialises badly when
    # interpreted, so the number only ever read as a bogus regression
    # against the jnp oracle.  What transfers to the TPU roofline is
    # correctness at the bench shape + the bytes the tiling avoids, so
    # that is what the kernel row now reports; a timed row returns when
    # the compiled path lands.
    got = fk.flash_attention_pallas(q, kk, v, bq=64, bk=64, interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
    naive_bytes = 4 * (bb * h * s * s)  # the materialised logits the kernel avoids
    rows.append(("flash_attention_ref", t_ref * 1e6, "us jnp (materialises S^2)"))
    rows.append((
        "flash_attention_interpret_parity",
        float(np.allclose(got, want, rtol=2e-3, atol=2e-3)),
        f"kernel == jnp oracle at (1,4,256,64) (1.0 = match); avoids "
        f"{naive_bytes/2**20:.0f} MiB logits round-trip; no interpret-mode "
        "wall-clock by design — compiled-path bench tracked in ROADMAP",
    ))

    # decode attention
    from repro.kernels.decode_attention import kernel as dk, ref as dref

    bq, hq, sq, dq = 4, 8, 1024, 64
    q1 = jax.random.normal(key, (bq, hq, dq))
    kc = jax.random.normal(key, (bq, sq, hq, dq))
    vc = jax.random.normal(key, (bq, sq, hq, dq))
    t_ref, want = timeit(jax.jit(lambda q, k, v: dref.decode_attention(q, k, v, jnp.int32(900))), q1, kc, vc)
    t_k, got = timeit(
        lambda q, k, v: dk.decode_attention_pallas(q, k, v, jnp.int32(900), bs=256, interpret=True),
        q1, kc, vc,
    )
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
    rows.append(("decode_attention_ref", t_ref * 1e6, "us jnp"))
    rows.append(("decode_attention_pallas_interp", t_k * 1e6, "us interpret"))

    # fused swiglu
    from repro.kernels.swiglu import kernel as gk, ref as gref

    t_, d_, f_ = 256, 128, 512
    x = jax.random.normal(key, (t_, d_))
    wg = jax.random.normal(key, (d_, f_)) * 0.05
    wu = jax.random.normal(key, (d_, f_)) * 0.05
    wo = jax.random.normal(key, (f_, d_)) * 0.05
    t_ref, want = timeit(jax.jit(gref.swiglu), x, wg, wu, wo)
    t_k, got = timeit(
        lambda *a: gk.swiglu_pallas(*a, bt=128, bf=128, interpret=True), x, wg, wu, wo
    )
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)
    hidden_bytes = 4 * t_ * f_ * 2
    rows.append(("swiglu_ref", t_ref * 1e6, "us jnp"))
    rows.append((
        "swiglu_pallas_interp", t_k * 1e6,
        f"us interpret; keeps {hidden_bytes/2**20:.1f} MiB hidden in VMEM",
    ))
    return rows


def main() -> None:
    for name, val, note in run():
        print(f"{name},{val:.1f},{note}")


if __name__ == "__main__":
    main()
