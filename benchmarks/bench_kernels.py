"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference on CPU.

Wall time in interpret mode is NOT TPU performance — the deliverable here
is (a) correctness at benchmark shapes and (b) the arithmetic-intensity
table each kernel is designed around (FLOPs vs bytes from the BlockSpec
tiling), which is what transfers to the TPU roofline.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def timeit(fn, *args, repeat=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat, out


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    key = jax.random.PRNGKey(0)

    # erlang_c: the analytic core's hot recurrence (DESIGN.md §12)
    from repro.kernels.erlang_c import kernel as ek, ref as eref

    a = jnp.linspace(0.5, 256.0, 128, dtype=jnp.float32)
    t_ref, want = timeit(lambda a: eref.erlang_b_table(a, k_hi=512), a)
    t_k, got = timeit(
        lambda a: ek.erlang_b_table_pallas(a, k_hi=512, interpret=True), a
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)
    rows.append(("erlang_b_table_ref", t_ref * 1e6, "us lax.scan, 128 lanes x k=512"))
    rows.append(("erlang_b_table_pallas_interp", t_k * 1e6, "us interpret (correctness run)"))

    # l2_match: the paper's matcher bolt
    from repro.kernels.l2_match import kernel as lk, ref as lref

    m, n, d = 256, 128, 64
    a = jax.random.normal(key, (m, d))
    b = jax.random.normal(key, (n, d))
    t_ref, want = timeit(jax.jit(lref.pairwise_sq_l2), a, b)
    t_k, got = timeit(
        lambda a, b: lk.pairwise_sq_l2_pallas(a, b, interpret=True), a, b
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    flops = 2 * m * n * d
    bytes_ = 4 * (m * d + n * d + m * n)
    rows.append(("l2_match_ref", t_ref * 1e6, f"us jnp ({flops/bytes_:.1f} flop/byte)"))
    rows.append(("l2_match_pallas_interp", t_k * 1e6, "us interpret (correctness run)"))

    # flash attention
    from repro.kernels.flash_attention import kernel as fk, ref as fref

    bb, h, s, dh = 1, 4, 256, 64
    q = jax.random.normal(key, (bb, h, s, dh))
    kk = jax.random.normal(key, (bb, h, s, dh))
    v = jax.random.normal(key, (bb, h, s, dh))
    t_ref, want = timeit(jax.jit(lambda q, k, v: fref.attention(q, k, v)), q, kk, v)
    # The interpret-mode WALL-CLOCK row is deliberately gone (ROADMAP
    # kernels item): the online-softmax recurrence serialises badly when
    # interpreted, so the number only ever read as a bogus regression
    # against the jnp oracle.  What transfers to the TPU roofline is
    # correctness at the bench shape + the bytes the tiling avoids, so
    # that is what the kernel row now reports; a timed row returns when
    # the compiled path lands.
    got = fk.flash_attention_pallas(q, kk, v, bq=64, bk=64, interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
    naive_bytes = 4 * (bb * h * s * s)  # the materialised logits the kernel avoids
    rows.append(("flash_attention_ref", t_ref * 1e6, "us jnp (materialises S^2)"))
    rows.append((
        "flash_attention_interpret_parity",
        float(np.allclose(got, want, rtol=2e-3, atol=2e-3)),
        f"kernel == jnp oracle at (1,4,256,64) (1.0 = match); avoids "
        f"{naive_bytes/2**20:.0f} MiB logits round-trip; no interpret-mode "
        "wall-clock by design — compiled-path bench tracked in ROADMAP",
    ))

    # decode attention
    from repro.kernels.decode_attention import kernel as dk, ref as dref

    bq, hq, sq, dq = 4, 8, 1024, 64
    q1 = jax.random.normal(key, (bq, hq, dq))
    kc = jax.random.normal(key, (bq, sq, hq, dq))
    vc = jax.random.normal(key, (bq, sq, hq, dq))
    t_ref, want = timeit(jax.jit(lambda q, k, v: dref.decode_attention(q, k, v, jnp.int32(900))), q1, kc, vc)
    t_k, got = timeit(
        lambda q, k, v: dk.decode_attention_pallas(q, k, v, jnp.int32(900), bs=256, interpret=True),
        q1, kc, vc,
    )
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
    rows.append(("decode_attention_ref", t_ref * 1e6, "us jnp"))
    rows.append(("decode_attention_pallas_interp", t_k * 1e6, "us interpret"))

    # fused swiglu
    from repro.kernels.swiglu import kernel as gk, ref as gref

    t_, d_, f_ = 256, 128, 512
    x = jax.random.normal(key, (t_, d_))
    wg = jax.random.normal(key, (d_, f_)) * 0.05
    wu = jax.random.normal(key, (d_, f_)) * 0.05
    wo = jax.random.normal(key, (f_, d_)) * 0.05
    t_ref, want = timeit(jax.jit(gref.swiglu), x, wg, wu, wo)
    t_k, got = timeit(
        lambda *a: gk.swiglu_pallas(*a, bt=128, bf=128, interpret=True), x, wg, wu, wo
    )
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)
    hidden_bytes = 4 * t_ * f_ * 2
    rows.append(("swiglu_ref", t_ref * 1e6, "us jnp"))
    rows.append((
        "swiglu_pallas_interp", t_k * 1e6,
        f"us interpret; keeps {hidden_bytes/2**20:.1f} MiB hidden in VMEM",
    ))

    # fused batch-decide: offered load -> Program-4 allocation in one pass
    from repro.kernels.decide_fused import ops as ddops, ref as ddref

    rng = np.random.default_rng(0)
    db, dn, dk_hi = 16, 8, 512
    lam = np.abs(rng.normal(3.0, 1.5, (db, dn))).astype(np.float32)
    mu = (np.abs(rng.normal(5.0, 1.0, (db, dn))) + 1.0).astype(np.float32)
    group = np.zeros((db, dn), dtype=bool)
    alpha = np.zeros((db, dn), dtype=np.float32)
    active = np.ones((db, dn), dtype=bool)
    k_cur = rng.integers(1, 6, (db, dn)).astype(np.int32)
    k_max = np.full(db, 40, dtype=np.int32)
    d_args = (lam, mu)
    d_kw = dict(group=group, alpha=alpha, active=active, k_cur=k_cur, k_max=k_max)

    # interpret-parity gate at a cheap shape: the Pallas kernel's integer
    # decision surface must equal the oracle's exactly
    pb, pk = (2, 32) if smoke else (4, 64)
    p_kw = {k: v[:pb] for k, v in d_kw.items() if k != "k_max"}
    p_kw["k_max"] = k_max[:pb]
    got = ddops.batch_decide(lam[:pb], mu[:pb], k_hi=pk, j_cap=40,
                             force_kernel=True, interpret=True, **p_kw)
    want = ddref.batch_decide(lam[:pb], mu[:pb], k_hi=pk, j_cap=40, **p_kw)
    parity = float(
        bool(np.array_equal(np.asarray(got[0]), np.asarray(want[0]))
             and np.array_equal(np.asarray(got[1]), np.asarray(want[1]))
             and np.allclose(got[2], want[2], rtol=1e-4, atol=1e-6)
             and np.allclose(got[3], want[3], rtol=1e-4, atol=1e-6))
    )
    rows.append((
        "decide_fused_interpret_parity", parity,
        f"kernel == jnp oracle at ({pb},{dn},k_hi={pk}) (1.0 = match); "
        "k4/k_start exact, T gathers at kernel tolerance",
    ))

    # compiled CPU-jit decide latency at the ISSUE shape: two-pass
    # (full-window sort selection, unroll=1) vs fused (j_cap window +
    # threshold bisection + tuned unroll) — the 1.6 ms/tick gate.  Not
    # reduced under --smoke: each call is ~ms and fewer reps makes the
    # gate label flap on dispatch jitter.
    reps = 10
    twopass = jax.jit(lambda l, m: ddref.batch_decide(
        l, m, k_hi=dk_hi, j_cap=None, unroll=1, **d_kw))
    fused = jax.jit(lambda l, m: ddref.batch_decide(
        l, m, k_hi=dk_hi, j_cap=48, unroll=ddops.DEFAULT_UNROLL, **d_kw))
    t_two, out_two = timeit(twopass, *d_args, repeat=reps)
    t_fus, out_fus = timeit(fused, *d_args, repeat=reps)
    for a, b in zip(out_two[:2], out_fus[:2]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rows.append((
        "decide_twopass_ms", t_two * 1e3,
        f"ms/tick two-pass erlang_c->gain_topr at B={db} N={dn} K={dk_hi} (cpu jit)",
    ))
    rows.append((
        "decide_fused_ms", t_fus * 1e3,
        f"ms/tick fused decide, same shape, j_cap=48 unroll="
        f"{ddops.DEFAULT_UNROLL} ({t_two / t_fus:.1f}x, gate < 1.6 ms: "
        f"{'PASS' if t_fus * 1e3 < 1.6 else 'FAIL'})",
    ))

    # HBM traffic the fusion deletes: two-pass round-trips the sojourn
    # table T [B,N,K+1] and the gain table G [B,N,K] through memory
    # (write + read each); fused keeps both VMEM-resident
    saved = 2 * 4 * (db * dn * (dk_hi + 1) + db * dn * dk_hi)
    rows.append((
        "decide_fused_hbm_bytes_saved", float(saved),
        f"bytes/decide not round-tripped at B={db} N={dn} K={dk_hi} "
        f"({saved/2**20:.2f} MiB: T and G stay VMEM-resident)",
    ))

    # block-shape tuning hook: persist the Erlang scan unroll sweep so
    # DEFAULT_UNROLL stays auditable per host
    a_sweep = jnp.asarray(np.abs(rng.normal(4.0, 3.0, db * dn)), dtype=jnp.float32)
    sweep = (1, ddops.DEFAULT_UNROLL) if smoke else ddops.UNROLL_SWEEP
    best, timings = ddops.autotune_unroll(
        a_sweep, k_hi=dk_hi, sweep=sweep, reps=1 if smoke else 5
    )
    for u, sec in sorted(timings.items()):
        rows.append((
            f"erlang_unroll_{u}", sec * 1e6,
            f"us erlang_b_table [{db * dn},{dk_hi}] scan unroll={u}",
        ))
    rows.append((
        "erlang_unroll_best", float(best),
        f"autotuned scan unroll (DEFAULT_UNROLL={ddops.DEFAULT_UNROLL}; "
        "bitwise-safe, perf-only)",
    ))

    # compiled-backend rows only where a real accelerator is attached —
    # interpret wall-clock is not TPU performance (see module docstring)
    if jax.default_backend() in ("tpu", "gpu"):
        t_comp, out_comp = timeit(
            lambda l, m: ddops.batch_decide(
                l, m, k_hi=dk_hi, j_cap=48, force_kernel=True, **d_kw),
            *d_args, repeat=reps,
        )
        np.testing.assert_array_equal(
            np.asarray(out_comp[0]), np.asarray(out_fus[0])
        )
        rows.append((
            "decide_fused_compiled_ms", t_comp * 1e3,
            f"ms/tick compiled pallas_call on {jax.default_backend()}",
        ))
    return rows


def main() -> None:
    for name, val, note in run():
        print(f"{name},{val:.1f},{note}")


if __name__ == "__main__":
    main()
