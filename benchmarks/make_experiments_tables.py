"""Regenerate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run records (so the document is reproducible from artifacts).

  PYTHONPATH=src python -m benchmarks.make_experiments_tables > /tmp/tables.md
"""

from __future__ import annotations


from .roofline import load, table


def dryrun_table(mesh: str) -> str:
    lines = [
        "| arch | shape | status | lower s | compile s | args GB/dev | temp GB/dev | "
        "collective ops (counts) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for rec in load(mesh):
        if rec.get("status") == "skipped":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | skipped | — | — | — | — | "
                f"{rec['reason'][:70]} |"
            )
            continue
        if rec.get("status") != "ok":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | ERROR | — | — | — | — | "
                f"{rec.get('error','')[:70]} |"
            )
            continue
        m = rec.get("memory_analysis", {})
        counts = rec["roofline"]["collectives"]["count_by_kind"]
        cstr = " ".join(f"{k}:{int(v)}" for k, v in sorted(counts.items()))
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | ok | {rec.get('lower_s', 0):.1f} | "
            f"{rec.get('compile_s', 0):.1f} | "
            f"{m.get('argument_size_in_bytes', 0)/2**30:.2f} | "
            f"{m.get('temp_size_in_bytes', 0)/2**30:.2f} | {cstr} |"
        )
    return "\n".join(lines)


def main() -> None:
    for mesh in ("pod16x16", "pod2x16x16"):
        print(f"\n### Dry-run — {mesh}\n")
        print(dryrun_table(mesh))
    print("\n### Roofline — pod16x16 (single pod; per §Roofline spec)\n")
    print(table(load("pod16x16")))


if __name__ == "__main__":
    main()
