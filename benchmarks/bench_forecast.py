"""Proactive forecast/MPC control vs the reactive trigger (DESIGN.md §15).

The claim: the §11 overload trigger only fires *after* a deadline window
is already degrading — every reactive scale-out pays at least one control
tick of misses while the backlog it reacted to drains.  The forecast/MPC
plane (``repro/forecast``) sizes ahead of predicted rates instead, so on
forecastable load shapes it should dominate the reactive controller on
*both* axes at once: fewer deadline misses/drops AND no more provisioned
processors.  On unforecastable load the confidence gate (MASE/sMAPE)
must close and hand every decision back to the reactive path — predict
only when the predictor has earned it.

Scenarios (all seed-pinned, numpy float64 twin, identical sim randomness
for both controllers until their allocations diverge):

* ``flash``   — the paper's VLD chain under a flash-crowd *ramp*
  (10 -> 30 events/s over 40 s, replay trace): holt double-exponential
  smoothing sees the ramp's trend one window in and extrapolates over
  the MPC horizon, while the reactive controller is always one
  measurement window behind the slope;
* ``diurnal`` — the paper's FPD graph under a day/night sinusoid, four
  periods: the seasonal predictor replays last period's rates and
  pre-provisions every upswing (``min_scored`` = one full season, so the
  gate only opens once the season buffer is real history);
* ``mmpp``    — an adversarial 2-state MMPP (4 <-> 28 events/s, fast
  random switching): unforecastable by construction, so the gate must
  keep the MPC out (``fallback_fraction`` ~ 1).

Gates (asserted, so CI fails loudly on regression):

* flash + diurnal: proactive strictly fewer warm-tick deadline misses,
  drops <= reactive, mean provisioned cost (k_total over warm ticks)
  <= reactive;
* mmpp: fallback fraction >= 0.8;
* numpy-twin vs jit predictor + planner agreement <= 1e-9 under x64.

``--smoke`` shortens the mmpp run; the flash/diurnal gates are cheap and
deterministic, so they run (and are asserted) in both modes.
"""

from __future__ import annotations

import numpy as np

from repro.api.session import ScenarioRunner
from repro.forecast import MPCConfig, PredictorParams
from repro.streaming.scenarios import ArrivalTrace, fpd_scenario, vld_scenario

AGREEMENT_ATOL = 1e-9


# --------------------------------------------------------------------------- #
# Scenario + config builders (calibration notes: the flash deadline/queue
# pair is chosen so the reactive lag misses are real but recoverable, and
# the long post-ramp tail is where the MPC's lean holds pay the cost back)
# --------------------------------------------------------------------------- #
def _flash_scenario():
    t5 = np.arange(0.0, 231.0, 5.0)
    ramp = np.interp(t5, [0, 80, 120, 140, 170, 230], [10, 10, 30, 30, 12, 12])
    return vld_scenario(
        name="flash-ramp",
        traces={"extract": ArrivalTrace(kind="replay", samples=tuple(ramp),
                                        sample_dt=5.0)},
        t_max=1.0, queue_capacity=40, machine_size=1, horizon=230.0,
    )


def _flash_cfg() -> MPCConfig:
    return MPCConfig(
        horizon=3, window=12, min_scored=2, headroom=1.1,
        scale_in_hysteresis=0.7,
        predictor=PredictorParams(kind="holt", alpha=0.6, beta=0.4),
    )


def _diurnal_scenario():
    return fpd_scenario(
        name="diurnal-4p",
        traces={"generate": ArrivalTrace(kind="diurnal", rate=15.0,
                                         amplitude=11.0, period=80.0)},
        horizon=320.0, queue_capacity=300, t_max=1.2,
    )


def _diurnal_cfg() -> MPCConfig:
    # One full season of scored history before the gate opens: a seasonal
    # predictor with a back-filled buffer is a constant predictor.
    return MPCConfig(
        horizon=4, window=32, min_scored=16, smape_gate=0.4,
        predictor=PredictorParams(kind="seasonal", season=16),
    )


def _mmpp_scenario(horizon: float):
    return vld_scenario(
        name="mmpp-adversarial",
        traces={"extract": ArrivalTrace(kind="mmpp", rate=4.0, peak=28.0,
                                        switch01=0.08, switch10=0.08)},
        t_max=1.0, queue_capacity=150, machine_size=1, horizon=horizon,
    )


def _warm_stats(report) -> dict:
    tr = report.trajectory
    warm = np.asarray(tr["warm"], dtype=bool)
    miss = np.asarray(tr["miss"], dtype=bool)
    k = np.asarray(tr["k_total"], dtype=float)
    out = {
        "misses": int((miss & warm).sum()),
        "cost": float(k[warm].mean()),
        "drops": float(report.drop_rate),
    }
    if "mpc_used" in tr:
        out["mpc_frac"] = float(np.asarray(tr["mpc_used"], bool)[warm].mean())
    return out


def _compare(scenario, cfg: MPCConfig, tick: float):
    re = ScenarioRunner([scenario], tick_interval=tick,
                        backend="numpy").run()[0]
    pro = ScenarioRunner([scenario], tick_interval=tick, backend="numpy",
                         proactive=cfg).run()[0]
    return _warm_stats(re), _warm_stats(pro)


def _twin_jit_agreement() -> float:
    """max |numpy twin - jit| over predictor forecasts and the full MPC
    planner outputs on a random batch, under x64."""
    import jax
    import jax.numpy as jnp

    from repro.forecast import forecast_rates, mpc_plan
    from repro.kernels.gain_topr import ops as topr_ops

    with jax.experimental.enable_x64():
        rng = np.random.default_rng(42)
        b, n, w, hzn, k_hi = 4, 3, 12, 3, 32
        hist = rng.uniform(2.0, 20.0, (b, w, n))
        worst = 0.0
        for kind in ("ewma", "holt", "seasonal"):
            pp = PredictorParams(kind=kind, alpha=0.6, beta=0.4,
                                 season=4 if kind == "seasonal" else 0)
            f_np = forecast_rates(hist, hzn, pp, xp=np)
            f_j = jax.jit(
                lambda h, pp=pp: forecast_rates(h, hzn, pp, xp=jnp)
            )(jnp.asarray(hist))
            worst = max(worst, float(np.max(np.abs(f_np - np.asarray(f_j)))))

        cfg = MPCConfig(horizon=hzn, window=w)
        lam_pred = rng.uniform(2.0, 20.0, (b, hzn, n))
        q0 = rng.uniform(0.0, 5.0, (b, n))
        k_cur = rng.integers(1, 6, (b, n)).astype(np.int64)
        kw = dict(
            mu=rng.uniform(2.0, 8.0, (b, n)),
            group=np.zeros((b, n)),
            alpha=np.zeros((b, n)),
            speed=np.ones((b, n)),
            active=np.ones((b, n), dtype=bool),
            src_mask=(np.arange(n)[None, :] == 0).repeat(b, axis=0),
            cap_queue=np.full((b, n), np.inf),
            t_max=np.full(b, 2.5),
            k_max=np.full(b, 48, dtype=np.int64),
            span=10.0, cfg=cfg, k_hi=k_hi,
        )
        out_np = mpc_plan(lam_pred, q0, k_cur, xp=np, **kw)
        out_j = jax.jit(
            lambda lp, q, k: mpc_plan(lp, q, k, xp=jnp,
                                      topr=topr_ops.gain_topr, **kw)
        )(jnp.asarray(lam_pred), jnp.asarray(q0), jnp.asarray(k_cur))
        for a, bj in zip(out_np, out_j):
            av, bv = np.asarray(a, dtype=float), np.asarray(bj, dtype=float)
            fin = np.isfinite(av) & np.isfinite(bv)
            if not np.array_equal(np.isfinite(av), np.isfinite(bv)):
                return float("inf")
            if fin.any():
                worst = max(worst, float(np.max(np.abs(av[fin] - bv[fin]))))
    return worst


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []

    def gate(tag, re, pro):
        rows.append((f"{tag}_misses_reactive", float(re["misses"]),
                     "warm-tick deadline misses, reactive trigger"))
        rows.append((f"{tag}_misses_proactive", float(pro["misses"]),
                     "warm-tick deadline misses, forecast/MPC"))
        rows.append((f"{tag}_drops_reactive", re["drops"], "drop rate, reactive"))
        rows.append((f"{tag}_drops_proactive", pro["drops"], "drop rate, proactive"))
        rows.append((f"{tag}_cost_reactive", re["cost"],
                     "mean provisioned processors over warm ticks"))
        rows.append((f"{tag}_cost_proactive", pro["cost"],
                     "mean provisioned processors over warm ticks"))
        rows.append((f"{tag}_mpc_fraction", pro["mpc_frac"],
                     "fraction of warm ticks the MPC plan was committed"))
        assert pro["misses"] < re["misses"], (
            f"{tag}: proactive misses {pro['misses']} not strictly fewer "
            f"than reactive {re['misses']}")
        assert pro["drops"] <= re["drops"], (
            f"{tag}: proactive drops {pro['drops']} > reactive {re['drops']}")
        assert pro["cost"] <= re["cost"], (
            f"{tag}: proactive cost {pro['cost']} > reactive {re['cost']}")
        rows.append((f"{tag}_gate", 1.0,
                     "proactive strictly fewer misses, drops <=, cost <="))

    # --- flash-crowd ramp (holt trend lookahead) ------------------------- #
    re, pro = _compare(_flash_scenario(), _flash_cfg(), tick=10.0)
    gate("flash", re, pro)

    # --- diurnal sinusoid (seasonal predictor) --------------------------- #
    re, pro = _compare(_diurnal_scenario(), _diurnal_cfg(), tick=5.0)
    gate("diurnal", re, pro)

    # --- adversarial MMPP: the confidence gate must close ---------------- #
    mmpp = _mmpp_scenario(horizon=100.0 if smoke else 150.0)
    pro = ScenarioRunner([mmpp], tick_interval=10.0, backend="numpy",
                         proactive=_flash_cfg()).run()[0]
    stats = _warm_stats(pro)
    fallback = 1.0 - stats["mpc_frac"]
    rows.append(("mmpp_fallback_fraction", fallback,
                 "warm ticks decided reactively under the adversarial MMPP "
                 "(confidence gate closed); gate >= 0.8"))
    assert fallback >= 0.8, f"mmpp fallback {fallback} < 0.8"

    # --- numpy twin vs jit agreement ------------------------------------- #
    diff = _twin_jit_agreement()
    rows.append(("twin_jit_max_abs_diff", diff,
                 f"predictors + mpc_plan, x64; gate <= {AGREEMENT_ATOL}"))
    assert diff <= AGREEMENT_ATOL, f"twin/jit diff {diff} > {AGREEMENT_ATOL}"
    return rows


def main() -> None:
    for name, val, note in run():
        print(f"{name},{val},{note}")


if __name__ == "__main__":
    main()
