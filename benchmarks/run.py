"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived`` CSV rows per benchmark.  Mapping:

  bench_overhead         -> paper Table II   (scheduling + measurement cost)
  bench_model_accuracy   -> paper Fig. 6 + 7 (allocation quality; est vs meas)
  bench_underestimation  -> paper Fig. 8     (out-of-model cost ratio)
  bench_rebalance        -> paper Fig. 9 + 10 (live rebalance, scale out/in)
  bench_overload         -> beyond-paper: flash-crowd overload (bounded
                            queues, drop agreement, "overloaded" decision)
  bench_scenarios        -> beyond-paper: scenario-matrix sweep (batch
                            simulator vs sequential DES, >= 20x gate)
  bench_controller       -> beyond-paper: batched control plane (fused jit
                            batch-decide vs per-scenario loop, >= 20x gate)
  bench_kernels          -> kernel layer (no paper table; TPU hot spots)
  bench_serving          -> beyond-paper: DRS-scheduled LLM serving
  bench_forecast         -> beyond-paper: proactive forecast/MPC control
                            vs the reactive trigger (miss/drop/cost gates,
                            confidence-gate fallback, twin-vs-jit parity)

Every run also persists its rows to a ``BENCH_<name>.json`` artifact at
the repo root (schema ``{bench, rows, smoke, timestamp}``); the CI
bench-smoke job uploads them, so the perf trajectory accumulates per PR
instead of evaporating with the job log.

Roofline tables (EXPERIMENTS §Dry-run/§Roofline) are produced separately
by ``python -m benchmarks.roofline`` from the dry-run records.
"""

from __future__ import annotations

import inspect
import json
import pathlib
import subprocess
import sys
import time
import traceback

from . import (
    bench_controller,
    bench_forecast,
    bench_kernels,
    bench_model_accuracy,
    bench_overhead,
    bench_overload,
    bench_rebalance,
    bench_scenarios,
    bench_serving,
    bench_underestimation,
)

SUITES = [
    ("overhead", bench_overhead),
    ("model_accuracy", bench_model_accuracy),
    ("underestimation", bench_underestimation),
    ("rebalance", bench_rebalance),
    ("overload", bench_overload),
    ("scenarios", bench_scenarios),
    ("controller", bench_controller),
    ("kernels", bench_kernels),
    ("serving", bench_serving),
    ("forecast", bench_forecast),
]

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def provenance() -> dict:
    """Attribution fields stamped into every artifact: without the commit
    and runtime that produced a number, the per-PR perf trajectory the
    bench-smoke job accumulates is not comparable across uploads."""
    try:
        git_sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT, text=True,
            capture_output=True, timeout=10, check=True,
        ).stdout.strip()
    except Exception:  # noqa: BLE001 — detached tarballs, missing git
        git_sha = "unknown"
    try:
        import jax

        jax_version, backend = jax.__version__, jax.default_backend()
    except Exception:  # noqa: BLE001 — numpy-only environments
        jax_version, backend = "unavailable", "none"
    return {"git_sha": git_sha, "jax_version": jax_version, "backend": backend}


def persist(name: str, rows: list, smoke: bool) -> pathlib.Path:
    """Write one suite's rows to ``BENCH_<name>.json`` at the repo root."""
    path = REPO_ROOT / f"BENCH_{name}.json"
    path.write_text(json.dumps(
        {
            "bench": name,
            "rows": [
                {"name": rn, "value": val, "note": note} for rn, val, note in rows
            ],
            "smoke": smoke,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            **provenance(),
        },
        indent=2,
    ) + "\n")
    return path


def main() -> None:
    # ``python -m benchmarks.run [suite] [--smoke]`` — smoke caps every
    # bench to seconds (CI drift gate); a suite name runs just that one.
    args = [a for a in sys.argv[1:] if a != "--smoke"]
    smoke = "--smoke" in sys.argv[1:]
    only = args[0] if args else None
    failures = 0
    for name, mod in SUITES:
        if only and only != name:
            continue
        print(f"# --- {name} ({mod.__name__}) ---", flush=True)
        t0 = time.time()
        try:
            kwargs = (
                {"smoke": smoke}
                if "smoke" in inspect.signature(mod.run).parameters
                else {}
            )
            rows = list(mod.run(**kwargs))
            for row_name, val, note in rows:
                print(f"{row_name},{val},{note}", flush=True)
            persist(name, rows, smoke)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,{traceback.format_exc().splitlines()[-1]}")
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
