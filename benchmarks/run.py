"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived`` CSV rows per benchmark.  Mapping:

  bench_overhead         -> paper Table II   (scheduling + measurement cost)
  bench_model_accuracy   -> paper Fig. 6 + 7 (allocation quality; est vs meas)
  bench_underestimation  -> paper Fig. 8     (out-of-model cost ratio)
  bench_rebalance        -> paper Fig. 9 + 10 (live rebalance, scale out/in)
  bench_overload         -> beyond-paper: flash-crowd overload (bounded
                            queues, drop agreement, "overloaded" decision)
  bench_kernels          -> kernel layer (no paper table; TPU hot spots)
  bench_serving          -> beyond-paper: DRS-scheduled LLM serving

Roofline tables (EXPERIMENTS §Dry-run/§Roofline) are produced separately
by ``python -m benchmarks.roofline`` from the dry-run records.
"""

from __future__ import annotations

import sys
import time
import traceback

from . import (
    bench_kernels,
    bench_model_accuracy,
    bench_overhead,
    bench_overload,
    bench_rebalance,
    bench_serving,
    bench_underestimation,
)

SUITES = [
    ("overhead", bench_overhead),
    ("model_accuracy", bench_model_accuracy),
    ("underestimation", bench_underestimation),
    ("rebalance", bench_rebalance),
    ("overload", bench_overload),
    ("kernels", bench_kernels),
    ("serving", bench_serving),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failures = 0
    for name, mod in SUITES:
        if only and only != name:
            continue
        print(f"# --- {name} ({mod.__name__}) ---", flush=True)
        t0 = time.time()
        try:
            for row_name, val, note in mod.run():
                print(f"{row_name},{val},{note}", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,{traceback.format_exc().splitlines()[-1]}")
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
