"""DRS-scheduled serving vs static splits (the paper's technique applied
to LLM prefill/decode disaggregation — DESIGN.md §2's flagship mapping).

For a grid of request rates, compare end-to-end latency under (a) the DRS
allocation from Program (4), (b) even static split, (c) decode-heavy and
prefill-heavy statics.  Rates come from the dry-run roofline when present.
"""

from __future__ import annotations

from pathlib import Path


from repro.serving.pipeline import ServingModel, StageRates, rates_from_dryrun
from repro.serving.router import ServingSimulation

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    try:
        rates = rates_from_dryrun("llama3.2-1b", RESULTS)
        note = "rates from llama3.2-1b dry-run"
    except (FileNotFoundError, KeyError):
        rates = StageRates(prefill_per_chip=0.5, decode_per_chip=40.0)
        note = "illustrative rates"
    model = ServingModel(rates, mean_output_tokens=32.0)
    k_max = 20
    # express rates relative to saturation so the bench is rate-agnostic
    sat = min(
        rates.prefill_per_chip * (k_max - 4) / (1 + model.group_alpha * (k_max - 5)),
        rates.decode_per_chip * (k_max - 4) / (1 + model.group_alpha * (k_max - 5)) / 32.0,
    )
    fracs = (0.3, 0.7) if smoke else (0.3, 0.5, 0.7)
    for frac in fracs:
        lam0 = sat * frac
        horizon = max(300.0, 150 / lam0) if smoke else max(1500.0, 800 / lam0)
        sim = ServingSimulation(model, lam0, horizon=horizon, warmup=50 / lam0, seed=int(frac * 100))
        k_min = sim.graph.topology().min_feasible_allocation()
        drs = sim.drs_allocation(k_max)
        lat_drs = sim.run(drs).mean_latency
        rows.append((f"serving_drs_rho{frac}", lat_drs * 1e3, f"ms | split {drs} | {note}"))
        budget = k_max - drs["tokenize"] - drs["detokenize"]
        for name, pre_frac in (("even", 0.5), ("prefill_heavy", 0.75), ("decode_heavy", 0.25)):
            pre = max(int(budget * pre_frac), int(k_min[1]))
            dec = budget - pre
            if dec < int(k_min[2]):
                rows.append((f"serving_{name}_rho{frac}", float("inf"), "infeasible (decode unstable)"))
                continue
            cand = {"tokenize": drs["tokenize"], "prefill": pre, "decode": dec,
                    "detokenize": drs["detokenize"]}
            lat = sim.run(cand).mean_latency
            rows.append((f"serving_{name}_rho{frac}", lat * 1e3, f"ms | split {cand}"))
    return rows


def main() -> None:
    for name, val, note in run():
        print(f"{name},{val:.1f},{note}")


if __name__ == "__main__":
    main()
